//! KV-cache feasibility model for the scheduler core (paper Eq. 20).
//!
//! The paper uses Eq. 20 (`token_num(m) = m·μ/σ`) only for *instance
//! assignment*: a wave is packed onto instances by total token footprint,
//! but nothing stops the SA search from forming a single batch whose
//! combined KV demand exceeds the instance's block pool — a plan the
//! engine then refuses at execution time. This module makes the block
//! pool a first-class input of the search:
//!
//! * [`KvConfig`] carries the pool geometry (tokens per block, pool size
//!   in blocks) and the enforcement [`KvMode`];
//! * per-job block footprints are precomputed into the per-wave
//!   [`crate::coordinator::pred_table::PredTable`];
//! * [`crate::coordinator::objective::IncrementalEval`] maintains
//!   per-batch block occupancy alongside its latency partials;
//! * the move generator and the annealing acceptance rule reject
//!   ([`KvMode::Hard`]) or penalize ([`KvMode::Soft`]) candidates that
//!   overcommit any batch.
//!
//! **Bit-identity contract**: with [`KvMode::Unlimited`] (the default) or
//! a `u64::MAX` pool, every excess is zero, no move is ever vetoed, and
//! the search draws the exact RNG stream of the pre-KV implementation —
//! enforced by `tests/kv_feasibility.rs`.
//!
//! A job's footprint is its *total* token count (prompt plus predicted
//! decode growth) rounded up to blocks: planned batches are static
//! (Eq. 10), so the engine reserves input + output KV up front and the
//! footprint is independent of the batch size the job executes at.
//!
//! **Phase-aware demand** ([`KvPhaseModel`]): reserving every job's full
//! footprint for the whole batch ([`KvPhaseModel::Reserve`], the legacy
//! and default model) overstates the true peak whenever output lengths
//! are staggered — a short job frees its blocks long before the batch
//! ends. [`KvPhaseModel::Phased`] instead models the lockstep-decode
//! occupancy profile exactly: every member holds its prompt blocks at
//! prefill, grows one token per decode step, and releases everything the
//! step it completes. [`phased_peak_blocks`] computes the exact peak of
//! that profile, which is what the evaluators charge a batch under
//! `Phased` (and what the phased engine pre-check in
//! [`crate::engine::sim::SimEngine`] admits against).

use crate::coordinator::profiler::MemoryModel;

/// Tokens per KV block (vLLM's default block size, shared with
/// [`crate::engine::kv_cache::KvCacheConfig`]).
pub const DEFAULT_BLOCK_TOKENS: usize = 16;

/// How KV-block pressure enters the objective.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum KvMode {
    /// Pre-KV behaviour: footprints are tracked but never constrain the
    /// search. Guarantees bit-identical trajectories to the legacy path.
    Unlimited,
    /// Hard feasibility: moves that would push any batch over the pool
    /// are vetoed before application, and the acceptance rule orders
    /// candidates by (excess, G) lexicographically — so a search seeded
    /// from an infeasible schedule descends into feasibility first and
    /// never accepts a regression in excess.
    Hard,
    /// Soft penalty: candidates are scored as `G − weight · excess_blocks`
    /// and the standard Metropolis rule applies to the penalized score.
    Soft {
        /// Penalty per excess block, in G units (G ≈ 1e-3 for ms-scale
        /// latencies, so weights around `1.0` make any overcommit dominate
        /// while still letting the search traverse infeasible states).
        weight: f64,
    },
}

/// How a planned batch's block **demand** is modelled (module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KvPhaseModel {
    /// Reserve-up-front: a batch demands the sum of its members' full
    /// footprints (prompt + predicted decode) for its whole duration.
    /// The legacy model — bit-identical to the pre-phase scheduler.
    #[default]
    Reserve,
    /// Phase-aware: a batch demands the exact peak of the lockstep
    /// prefill/decode occupancy profile, with per-member release at
    /// completion ([`phased_peak_blocks`]). Never exceeds the `Reserve`
    /// demand, so on the same pool a phased search can only batch more,
    /// never less.
    Phased,
}

/// KV-pool geometry + enforcement mode threaded through the search via
/// [`crate::coordinator::priority::annealing::SaParams::kv`].
///
/// ```
/// use slo_serve::coordinator::kv::{KvConfig, KvPhaseModel};
///
/// let kv = KvConfig::hard(64);
/// assert_eq!(kv.job_blocks(30, 3), 3); // 33 tokens -> 3 blocks of 16
/// assert_eq!(kv.batch_excess(70), 6);  // 6 blocks over the 64-block pool
/// assert!(kv.fits_alone(64) && !kv.fits_alone(65));
/// // demand-model escape hatch: Reserve is the default; Phased charges
/// // batches their exact lockstep-decode occupancy peak instead.
/// assert_eq!(kv.phase, KvPhaseModel::Reserve);
/// let phased = kv.with_phase(KvPhaseModel::Phased);
/// assert!(phased.phased() && phased.binding());
/// ```
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KvConfig {
    /// Tokens per block (must match the engine's allocator granularity).
    pub block_tokens: usize,
    /// Pool capacity in blocks; `u64::MAX` means unlimited.
    pub pool_blocks: u64,
    pub mode: KvMode,
    /// Batch demand model; [`KvPhaseModel::Reserve`] reproduces the
    /// pre-phase accounting bit for bit.
    pub phase: KvPhaseModel,
    /// **Quantile reservation column** (`lo_q`): multiplier applied to the
    /// predicted output length inside [`KvConfig::job_blocks`] before block
    /// rounding — typically
    /// [`crate::coordinator::predictor::LatencyPredictor::quantile`] at a
    /// conservative quantile, so KV footprints reserve for an upper
    /// output-length quantile while the latency objective keeps pricing
    /// the mean prediction. Exactly `1.0` (the default) is the escape
    /// hatch: footprints are the pre-quantile ones, bit for bit.
    pub lo_mult: f64,
    /// Modeled host↔device swap link bandwidth in GB/s (1 GB/s = 1 MB/ms),
    /// mirroring the engine's [`crate::engine::sim::PreemptConfig`]. `0.0`
    /// (the default) means the search does not price preemption: an
    /// overcommitted plan is vetoed/penalized exactly as before, bit for
    /// bit.
    pub swap_gbps: f64,
    /// Host swap-buffer capacity in blocks (provenance only; the
    /// per-block swap cost is what enters the objective).
    pub host_blocks: u64,
    /// KV block size in MB (`block_tokens × mb_per_token`), needed to
    /// turn the link bandwidth into a per-block transfer time.
    pub block_mb: f64,
}

impl Default for KvConfig {
    fn default() -> Self {
        KvConfig::UNLIMITED
    }
}

impl KvConfig {
    /// The legacy configuration: footprints tracked, nothing enforced.
    pub const UNLIMITED: KvConfig = KvConfig {
        block_tokens: DEFAULT_BLOCK_TOKENS,
        pool_blocks: u64::MAX,
        mode: KvMode::Unlimited,
        phase: KvPhaseModel::Reserve,
        lo_mult: 1.0,
        swap_gbps: 0.0,
        host_blocks: 0,
        block_mb: 0.0,
    };

    /// Hard-feasibility pool of `pool_blocks` blocks.
    pub fn hard(pool_blocks: u64) -> KvConfig {
        KvConfig {
            block_tokens: DEFAULT_BLOCK_TOKENS,
            pool_blocks,
            mode: KvMode::Hard,
            ..KvConfig::UNLIMITED
        }
    }

    /// Soft-penalty pool of `pool_blocks` blocks.
    pub fn soft(pool_blocks: u64, weight: f64) -> KvConfig {
        KvConfig {
            block_tokens: DEFAULT_BLOCK_TOKENS,
            pool_blocks,
            mode: KvMode::Soft { weight },
            ..KvConfig::UNLIMITED
        }
    }

    /// This configuration with a different batch demand model.
    pub fn with_phase(self, phase: KvPhaseModel) -> KvConfig {
        KvConfig { phase, ..self }
    }

    /// This configuration with the quantile reservation multiplier set
    /// (see the `lo_mult` field docs). Non-finite or sub-1 multipliers are
    /// clamped to `1.0` — reservations never shrink below the prediction.
    pub fn with_lo_mult(self, lo_mult: f64) -> KvConfig {
        let lo_mult = if lo_mult.is_finite() { lo_mult.max(1.0) } else { 1.0 };
        KvConfig { lo_mult, ..self }
    }

    /// Output length the reservation column charges a job for: the point
    /// prediction under the exact head (`lo_mult == 1.0`, same value bit
    /// for bit), a ceil-scaled conservative quantile otherwise.
    #[inline]
    pub fn reserved_lo(&self, output_len: usize) -> usize {
        if self.lo_mult == 1.0 {
            output_len
        } else {
            (output_len as f64 * self.lo_mult).ceil() as usize
        }
    }

    /// True when batch demand uses the phase-aware occupancy model.
    #[inline]
    pub fn phased(&self) -> bool {
        matches!(self.phase, KvPhaseModel::Phased)
    }

    /// Derive a pool from a memory budget through Eq. 20
    /// (`token_num(m) = m·μ/σ`, then blocks at `block_tokens` granularity).
    pub fn from_pool_mb(
        pool_mb: f64,
        mem: &MemoryModel,
        block_tokens: usize,
        mode: KvMode,
    ) -> KvConfig {
        let block_tokens = block_tokens.max(1);
        KvConfig {
            block_tokens,
            pool_blocks: pool_blocks_from_mb(pool_mb, mem, block_tokens),
            mode,
            ..KvConfig::UNLIMITED
        }
    }

    /// Blocks needed to hold `tokens` tokens (≥ 1 block, mirroring the
    /// engine allocator: even an empty sequence pins one block).
    #[inline]
    pub fn blocks_for_tokens(&self, tokens: usize) -> u64 {
        blocks_for(tokens, self.block_tokens)
    }

    /// Total KV footprint of one job: prompt + the decode growth the
    /// reservation column charges (the point prediction by default, a
    /// conservative output-length quantile when `lo_mult > 1` — see
    /// [`KvConfig::reserved_lo`]). The engine reserves both up front for a
    /// planned batch.
    #[inline]
    pub fn job_blocks(&self, input_len: usize, output_len: usize) -> u64 {
        self.blocks_for_tokens(input_len + self.reserved_lo(output_len))
    }

    /// Footprint right after prefill (before any decode growth) —
    /// diagnostics for peak-occupancy breakdowns.
    #[inline]
    pub fn prefill_blocks(&self, input_len: usize) -> u64 {
        self.blocks_for_tokens(input_len)
    }

    /// True when the pool can actually constrain the search: a finite pool
    /// under [`KvMode::Hard`] or [`KvMode::Soft`].
    #[inline]
    pub fn binding(&self) -> bool {
        !matches!(self.mode, KvMode::Unlimited) && self.pool_blocks != u64::MAX
    }

    /// This configuration with swap-preemption pricing enabled: an
    /// overcommitted plan is no longer vetoed outright but *priced* — the
    /// excess is assumed to be covered at execution by swap-preempting
    /// blocks over a `gbps` link (see [`KvConfig::preempt_score`]).
    pub fn with_swap(
        self,
        gbps: f64,
        block_mb: f64,
        host_blocks: u64,
    ) -> KvConfig {
        KvConfig { swap_gbps: gbps, block_mb, host_blocks, ..self }
    }

    /// Swap transfer time per block (ms): `block_mb / swap_gbps`
    /// (1 GB/s = 1 MB/ms). 0 when no link is configured.
    #[inline]
    pub fn swap_ms_per_block(&self) -> f64 {
        if self.swap_gbps > 0.0
            && self.swap_gbps.is_finite()
            && self.block_mb > 0.0
        {
            self.block_mb / self.swap_gbps
        } else {
            0.0
        }
    }

    /// True when the search prices overcommitment as preemption cost
    /// instead of vetoing/penalizing it: a binding pool with a configured
    /// swap link. With the default `swap_gbps == 0` this is always false
    /// and every acceptance path keeps its legacy arithmetic bit for bit.
    #[inline]
    pub fn prices_preemption(&self) -> bool {
        self.binding() && self.swap_ms_per_block() > 0.0
    }

    /// Preemption-priced score of a schedule: at zero excess this is `g`
    /// unchanged (same bits — the bit-identity hinge); an overcommitted
    /// schedule is scored as if its excess blocks each pay one swap-out
    /// plus one swap-in on the critical path, inflating the G
    /// denominator: `met / (total_e2e + 2·swap_ms_per_block·excess)`.
    /// Monotone in excess, so the search still descends toward
    /// feasibility — but a small overcommit with cheap swap can now
    /// outscore a feasible plan that sacrifices deadlines.
    #[inline]
    pub fn preempt_score(
        &self,
        g: f64,
        met: usize,
        total_e2e_ms: f64,
        excess_blocks: u64,
    ) -> f64 {
        if excess_blocks == 0 {
            g
        } else {
            let penalty_ms =
                2.0 * self.swap_ms_per_block() * excess_blocks as f64;
            met as f64 / (total_e2e_ms + penalty_ms)
        }
    }

    /// True when moves should be vetoed pre-application (hard mode only;
    /// soft mode lets the search traverse infeasible states, and a
    /// configured swap link turns hard vetoes into priced acceptance —
    /// see [`KvConfig::prices_preemption`]).
    #[inline]
    pub fn vetoes_moves(&self) -> bool {
        matches!(self.mode, KvMode::Hard)
            && self.pool_blocks != u64::MAX
            && !self.prices_preemption()
    }

    /// Blocks by which one batch's occupancy exceeds the pool (0 when the
    /// config is not binding).
    #[inline]
    pub fn batch_excess(&self, batch_blocks: u64) -> u64 {
        if self.binding() {
            batch_blocks.saturating_sub(self.pool_blocks)
        } else {
            0
        }
    }

    /// Can a job of `blocks` blocks ever execute (alone in a batch)?
    #[inline]
    pub fn fits_alone(&self, blocks: u64) -> bool {
        !self.binding() || blocks <= self.pool_blocks
    }

    /// Soft-mode score: `G − weight · excess`. Returns `g` untouched (same
    /// bits) at zero excess, preserving the bit-identity contract.
    #[inline]
    pub fn soft_score(g: f64, excess_blocks: u64, weight: f64) -> f64 {
        if excess_blocks == 0 {
            g
        } else {
            g - weight * excess_blocks as f64
        }
    }
}

/// Exact peak block occupancy of one planned batch under phase-aware
/// execution ([`KvPhaseModel::Phased`]). `members` holds each member's
/// `(input_len, predicted_output_len)`.
///
/// Model (mirrors the engine's lockstep static-batch semantics): after
/// the batch has generated `g` tokens per member, a member with output
/// `o_i` holds `blocks(input_i + min(g, o_i))` blocks while alive, and
/// releases everything once it completes at `max(o_i, 1)` generated
/// tokens (per-member release at completion — the thing
/// [`KvPhaseModel::Reserve`] ignores; the `min` caps a member's KV at
/// its reserve footprint, zero-output requests included). Occupancy is
/// non-decreasing between completions, so the peak is attained at some
/// member's completion point:
///
/// ```text
/// peak = max over j of  Σ_{i alive at gⱼ} blocks(input_i + min(gⱼ, o_i))
///        where gⱼ = max(o_j, 1)
/// ```
///
/// O(b²) over the batch — b is bounded by `max_batch`, so this stays
/// cheap inside the SA hot path.
///
/// Bounds (enforced by tests): the peak never exceeds the `Reserve` sum
/// of full footprints, and never falls below any single member's full
/// footprint — which is what makes the footprint-sum greedy packer
/// conservative-but-sound under `Phased` and keeps the move veto's
/// arithmetic safe.
pub fn phased_peak_blocks(members: &[(usize, usize)], block_tokens: usize) -> u64 {
    phased_peak_over(members.len(), |i| members[i], block_tokens)
}

/// [`phased_peak_blocks`] over a *virtual* member set resolved through
/// `get` — the allocation-free form the move generator's veto uses to
/// price candidate batches (member list plus one added/substituted job)
/// without materializing them. The two entry points share this one
/// implementation so the veto can never diverge from the evaluators.
pub fn phased_peak_over(
    n: usize,
    get: impl Fn(usize) -> (usize, usize),
    block_tokens: usize,
) -> u64 {
    let mut peak = 0u64;
    for j in 0..n {
        let g = get(j).1.max(1);
        let mut occ = 0u64;
        for i in 0..n {
            let (input_i, out_i) = get(i);
            if out_i.max(1) >= g {
                occ += blocks_for(input_i + g.min(out_i), block_tokens);
            }
        }
        if occ > peak {
            peak = occ;
        }
    }
    peak
}

/// The scheduler-side block-rounding rule, shared by every footprint
/// computation ([`KvConfig::blocks_for_tokens`], instance assignment):
/// `⌈max(tokens, 1) / block_tokens⌉`. Must stay in lockstep with the
/// engine allocator's accounting
/// ([`crate::engine::kv_cache::BlockAllocator::blocks_needed`]) — the
/// search's occupancy sums are only a feasibility proof if both sides
/// round identically.
#[inline]
pub fn blocks_for(tokens: usize, block_tokens: usize) -> u64 {
    (tokens.max(1).div_ceil(block_tokens.max(1))) as u64
}

/// Greedily pack `order[from..]` into batches of at most `max_batch`
/// jobs whose block sums stay within `pool_blocks`, appending the batch
/// sizes to `batches`. Pass `u64::MAX` for an unconstrained pool (plain
/// fixed-size chunking). A job whose footprint alone exceeds the pool
/// still gets a singleton batch — callers reject such jobs upstream.
/// This is **the** feasible-packing rule, shared by the online seed
/// packing and the hard-mode repack fallback so the two can never
/// diverge. Packing always sums full footprints (`Reserve` accounting);
/// since a batch's phased peak never exceeds that sum, packings stay
/// feasible under [`KvPhaseModel::Phased`] too — conservative, and the
/// SA search is then free to re-batch more aggressively.
pub fn pack_greedy(
    order: &[usize],
    from: usize,
    job_blocks: &[u64],
    max_batch: usize,
    pool_blocks: u64,
    batches: &mut Vec<usize>,
) {
    let max_batch = max_batch.max(1);
    let mut size = 0usize;
    let mut blocks = 0u64;
    for &j in &order[from..] {
        let jb = job_blocks[j];
        if size == max_batch || (size > 0 && blocks + jb > pool_blocks) {
            batches.push(size);
            size = 0;
            blocks = 0;
        }
        size += 1;
        blocks += jb;
    }
    if size > 0 {
        batches.push(size);
    }
}

/// Eq. 20 pool derivation shared by the scheduler and the CLI: tokens a
/// memory budget can host (`m·μ/σ`), floored to whole blocks. NaN or
/// non-positive budgets yield an empty pool (a broken instance must not
/// look infinite).
///
/// Deliberately **conservative** relative to the engine allocator, which
/// sizes its pool without μ ([`crate::engine::kv_cache::KvCacheConfig`]):
/// Eq. 20's utility factor (μ < 1, paper §4.2) is headroom for
/// fragmentation and accounting slack, so the search plans against
/// `μ · pool` while the engine admits against the full pool — a plan
/// feasible under the scheduler's pool is always feasible at execution.
/// The *rounding* of individual footprints, by contrast, matches the
/// allocator exactly ([`blocks_for`]).
pub fn pool_blocks_from_mb(
    mem_mb: f64,
    mem: &MemoryModel,
    block_tokens: usize,
) -> u64 {
    (mem.token_capacity(mem_mb) / block_tokens.max(1)) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_rounding_mirrors_engine_allocator() {
        let kv = KvConfig::hard(100);
        assert_eq!(kv.blocks_for_tokens(0), 1); // empty seq pins a block
        assert_eq!(kv.blocks_for_tokens(1), 1);
        assert_eq!(kv.blocks_for_tokens(16), 1);
        assert_eq!(kv.blocks_for_tokens(17), 2);
        assert_eq!(kv.job_blocks(30, 3), 3); // 33 tokens -> 3 blocks
        assert_eq!(kv.prefill_blocks(30), 2);
    }

    #[test]
    fn unlimited_is_never_binding() {
        let kv = KvConfig::UNLIMITED;
        assert!(!kv.binding());
        assert!(!kv.vetoes_moves());
        assert_eq!(kv.batch_excess(u64::MAX - 1), 0);
        assert!(kv.fits_alone(u64::MAX));
        // finite pool under Unlimited mode is still legacy behaviour
        let legacy = KvConfig { pool_blocks: 4, ..KvConfig::UNLIMITED };
        assert!(!legacy.binding());
        assert_eq!(legacy.batch_excess(10), 0);
        // hard mode with an infinite pool never vetoes either
        let inf_hard = KvConfig::hard(u64::MAX);
        assert!(!inf_hard.binding());
        assert!(!inf_hard.vetoes_moves());
    }

    #[test]
    fn excess_and_modes() {
        let hard = KvConfig::hard(10);
        assert!(hard.binding() && hard.vetoes_moves());
        assert_eq!(hard.batch_excess(10), 0); // exact fit is feasible
        assert_eq!(hard.batch_excess(13), 3);
        assert!(!hard.fits_alone(11));
        let soft = KvConfig::soft(10, 0.5);
        assert!(soft.binding() && !soft.vetoes_moves());
    }

    #[test]
    fn soft_score_identity_at_zero_excess() {
        let g = 1.23456789e-3;
        assert_eq!(KvConfig::soft_score(g, 0, 7.0).to_bits(), g.to_bits());
        assert!(KvConfig::soft_score(g, 2, 0.5) < g);
    }

    #[test]
    fn pack_greedy_respects_both_caps() {
        // blocks: jobs 0..5 -> [3, 3, 2, 2, 2]; pool 6, max_batch 3
        let job_blocks = [3u64, 3, 2, 2, 2];
        let order = [0usize, 1, 2, 3, 4];
        let mut batches = Vec::new();
        pack_greedy(&order, 0, &job_blocks, 3, 6, &mut batches);
        // [0,1] = 6 (exact fit), then [2,3,4] = 6 (size and pool cap)
        assert_eq!(batches, vec![2, 3]);
        // unconstrained pool: plain fixed-size chunking
        let mut plain = Vec::new();
        pack_greedy(&order, 0, &job_blocks, 2, u64::MAX, &mut plain);
        assert_eq!(plain, vec![2, 2, 1]);
        // `from` skips a frozen prefix; appends after existing entries
        let mut tail = vec![9usize];
        pack_greedy(&order, 3, &job_blocks, 3, 6, &mut tail);
        assert_eq!(tail, vec![9, 2]);
    }

    #[test]
    fn phased_peak_matches_hand_computed_profile() {
        // A: 100 in / 10 out (full 7 blocks of 16); B: 100 in / 100 out
        // (full 13 blocks). Reserve charges 20; the lockstep profile peaks
        // when both are alive at g = 10: 2 × blocks(110) = 2 × 7 = 14.
        let members = [(100usize, 10usize), (100, 100)];
        assert_eq!(phased_peak_blocks(&members, 16), 14);
        let reserve: u64 = members
            .iter()
            .map(|&(i, o)| blocks_for(i + o, 16))
            .sum();
        assert_eq!(reserve, 20);
        // identical members never release early: phased == reserve
        assert_eq!(phased_peak_blocks(&[(100, 100); 2], 16), 26);
        // zero-output members complete at prefill holding their prompt
        assert_eq!(phased_peak_blocks(&[(15, 0)], 16), 1);
        assert_eq!(phased_peak_blocks(&[], 16), 0);
        // the closure form is the same computation
        let m = [(100usize, 10usize), (100, 100)];
        assert_eq!(phased_peak_over(2, |i| m[i], 16), 14);
    }

    #[test]
    fn phased_peak_bounds() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0x9A5E);
        for _ in 0..200 {
            let b = 1 + rng.below(8);
            let members: Vec<(usize, usize)> = (0..b)
                .map(|_| (1 + rng.below(800), rng.below(300)))
                .collect();
            let peak = phased_peak_blocks(&members, 16);
            // bounds are against the *production* footprint (input +
            // output, no output clamp — what job_blocks/pack_greedy use)
            let reserve: u64 = members
                .iter()
                .map(|&(i, o)| blocks_for(i + o, 16))
                .sum();
            let max_member = members
                .iter()
                .map(|&(i, o)| blocks_for(i + o, 16))
                .max()
                .unwrap();
            assert!(peak <= reserve, "{members:?}: {peak} > reserve {reserve}");
            assert!(
                peak >= max_member,
                "{members:?}: {peak} < largest member {max_member}"
            );
        }
    }

    #[test]
    fn zero_output_singleton_peak_equals_its_footprint() {
        // regression: a block-aligned prompt with output 0 must not be
        // charged an extra phantom decode block — its peak is exactly its
        // reserve footprint, so fits_alone/admission/engine agree.
        let kv = KvConfig::hard(1);
        assert_eq!(kv.job_blocks(16, 0), 1);
        assert_eq!(phased_peak_blocks(&[(16, 0)], 16), 1);
        assert!(kv.fits_alone(phased_peak_blocks(&[(16, 0)], 16)));
        // and with_phase changes only the demand model
        let phased = kv.with_phase(KvPhaseModel::Phased);
        assert!(phased.phased() && !kv.phased());
        assert_eq!(phased.pool_blocks, kv.pool_blocks);
        assert_eq!(phased.mode, kv.mode);
    }

    #[test]
    fn quantile_reservation_column() {
        let kv = KvConfig::hard(100);
        // default: exact head — footprints bit-identical to pre-quantile
        assert_eq!(kv.lo_mult, 1.0);
        assert_eq!(kv.job_blocks(30, 10), KvConfig::hard(100).job_blocks(30, 10));
        // a 1.5× conservative column inflates the decode part only
        let q = kv.with_lo_mult(1.5);
        assert_eq!(q.reserved_lo(10), 15);
        assert_eq!(q.reserved_lo(0), 0);
        assert_eq!(q.job_blocks(30, 10), blocks_for(45, 16)); // 3 blocks
        assert!(q.job_blocks(30, 100) > kv.job_blocks(30, 100));
        // prompt-only footprints are untouched by the column
        assert_eq!(q.prefill_blocks(30), kv.prefill_blocks(30));
        // sub-1 / non-finite multipliers clamp to the exact head
        assert_eq!(kv.with_lo_mult(0.5).lo_mult, 1.0);
        assert_eq!(kv.with_lo_mult(f64::NAN).lo_mult, 1.0);
        // with_phase preserves the column; with_lo_mult preserves the mode
        assert_eq!(q.with_phase(KvPhaseModel::Phased).lo_mult, 1.5);
        assert_eq!(q.mode, kv.mode);
    }

    #[test]
    fn phased_peak_edge_cases() {
        // empty batch: nothing alive, zero occupancy
        assert_eq!(phased_peak_blocks(&[], 16), 0);
        // single job: peak is exactly its full footprint
        assert_eq!(
            phased_peak_blocks(&[(100, 60)], 16),
            blocks_for(160, 16)
        );
        assert_eq!(phased_peak_blocks(&[(1, 1)], 16), 1);
        // all-prefill-dominant (outputs ≤ 1): everyone completes at the
        // first token holding prompt + that token — peak == reserve sum
        let prefill_heavy = [(500usize, 1usize), (700, 0), (320, 1)];
        let reserve: u64 = prefill_heavy
            .iter()
            .map(|&(i, o)| blocks_for(i + o, 16))
            .sum();
        assert_eq!(phased_peak_blocks(&prefill_heavy, 16), reserve);
        // all-decode-dominant with equal outputs: no early release, so
        // the peak again equals the reserve sum …
        let decode_heavy = [(4usize, 400usize), (8, 400), (2, 400)];
        let reserve: u64 = decode_heavy
            .iter()
            .map(|&(i, o)| blocks_for(i + o, 16))
            .sum();
        assert_eq!(phased_peak_blocks(&decode_heavy, 16), reserve);
        // … while staggered outputs release early and peak strictly below
        let staggered = [(4usize, 40usize), (4, 400)];
        let reserve: u64 =
            staggered.iter().map(|&(i, o)| blocks_for(i + o, 16)).sum();
        assert!(phased_peak_blocks(&staggered, 16) < reserve);
    }

    #[test]
    fn phased_peak_bounded_by_reserve_sum_property() {
        use crate::util::prop::check;
        check("phased_peak ≤ reserve_sum (and ≥ max member)", 300, |rng| {
            let b = rng.below(9); // empty batches included
            let members: Vec<(usize, usize)> = (0..b)
                .map(|_| (rng.below(1200), rng.below(500)))
                .collect();
            let bt = 1 + rng.below(32);
            let peak = phased_peak_blocks(&members, bt);
            let reserve: u64 = members
                .iter()
                .map(|&(i, o)| blocks_for(i + o, bt))
                .sum();
            if peak > reserve {
                return Err(format!(
                    "{members:?} @ {bt}: peak {peak} > reserve {reserve}"
                ));
            }
            if let Some(max_member) = members
                .iter()
                .map(|&(i, o)| blocks_for(i + o, bt))
                .max()
            {
                if peak < max_member {
                    return Err(format!(
                        "{members:?} @ {bt}: peak {peak} < member {max_member}"
                    ));
                }
            } else if peak != 0 {
                return Err("empty batch with nonzero peak".into());
            }
            Ok(())
        });
    }

    #[test]
    fn preemption_pricing_gates_and_score() {
        // default: no link configured, nothing priced, vetoes unchanged
        let hard = KvConfig::hard(10);
        assert_eq!(hard.swap_ms_per_block(), 0.0);
        assert!(!hard.prices_preemption());
        assert!(hard.vetoes_moves());
        // a swap link on a binding hard pool flips vetoes into pricing
        let priced = hard.with_swap(8.0, 8.0, 64);
        assert_eq!(priced.swap_ms_per_block(), 1.0);
        assert!(priced.prices_preemption());
        assert!(!priced.vetoes_moves());
        // …but an unlimited pool never prices anything
        assert!(!KvConfig::UNLIMITED.with_swap(8.0, 8.0, 64).prices_preemption());
        // degenerate links are treated as absent
        assert!(!hard.with_swap(0.0, 8.0, 64).prices_preemption());
        assert!(!hard.with_swap(f64::INFINITY, 8.0, 64).prices_preemption());
        assert!(!hard.with_swap(8.0, 0.0, 64).prices_preemption());
        // score: bit-identical g at zero excess, monotone decreasing after
        let g = 2.0 / 3000.0;
        assert_eq!(priced.preempt_score(g, 2, 3000.0, 0).to_bits(), g.to_bits());
        let s1 = priced.preempt_score(g, 2, 3000.0, 5);
        let s2 = priced.preempt_score(g, 2, 3000.0, 50);
        assert!(s1 < g && s2 < s1, "score must fall with excess: {s1} {s2}");
        // 5 excess blocks at 1 ms/block charge 10 ms round-trip
        assert_eq!(s1, 2.0 / 3010.0);
    }

    #[test]
    fn eq20_pool_derivation() {
        let mem = MemoryModel { utility: 0.9, mb_per_token: 0.5 };
        // 1000 MB -> 1800 tokens -> 112 blocks of 16
        assert_eq!(pool_blocks_from_mb(1000.0, &mem, 16), 112);
        assert_eq!(pool_blocks_from_mb(0.0, &mem, 16), 0);
        assert_eq!(pool_blocks_from_mb(f64::NAN, &mem, 16), 0);
        let kv = KvConfig::from_pool_mb(1000.0, &mem, 16, KvMode::Hard);
        assert_eq!(kv.pool_blocks, 112);
        assert!(kv.vetoes_moves());
    }
}
