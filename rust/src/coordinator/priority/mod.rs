//! Priority mapping: the paper's core contribution (§4.3).
//!
//! * [`annealing`]  — simulated-annealing search (Algorithm 1), the
//!   production path (~1 ms overhead).
//! * [`exhaustive`] — `O(N!·2^N)` strawman used as the optimality baseline.
//! * [`moves`]      — the neighbourhood operators shared by the search.

pub mod annealing;
pub mod exhaustive;
pub mod moves;

pub use annealing::{
    priority_mapping, priority_mapping_full, priority_mapping_warm, SaParams,
    SaResult, SearchStats,
};
pub use exhaustive::{exhaustive_mapping, ExhaustiveResult, MAX_EXHAUSTIVE_N};
