//! Integration: TCP JSON-lines reactor over the sharded front door.

use std::sync::Arc;

use slo_serve::config::profiles::by_name;
use slo_serve::engine::sim::SimEngine;
use slo_serve::engine::Engine;
use slo_serve::server::{
    serve_tcp, Client, FrontDoor, FrontDoorConfig, TcpServer,
};
use slo_serve::util::json::Json;

fn boot(shards: usize) -> (TcpServer, Arc<FrontDoor>) {
    let profile = by_name("qwen7b-v100x2-vllm").unwrap();
    let mut cfg =
        FrontDoorConfig::new(profile.truth, profile.max_total_tokens);
    cfg.shards = shards;
    cfg.queue_depth = 64;
    cfg.stream_tokens = true;
    cfg.sa.max_batch = 4;
    cfg.sa.iters_per_temp = 5;
    let engines: Vec<Box<dyn Engine + Send>> = (0..shards)
        .map(|s| {
            Box::new(SimEngine::new(profile.clone(), 4, s as u64))
                as Box<dyn Engine + Send>
        })
        .collect();
    let door = FrontDoor::start(cfg, engines).unwrap();
    let server = serve_tcp(door.clone(), "127.0.0.1:0").unwrap();
    (server, door)
}

fn teardown(mut server: TcpServer, door: Arc<FrontDoor>) {
    server.stop();
    door.shutdown();
}

#[test]
fn generate_roundtrip() {
    let (server, door) = boot(1);
    let mut client = Client::connect(server.addr).unwrap();
    let reply = client
        .call(
            &Json::parse(
                r#"{"op":"generate","task":"chat","input_len":100,"max_tokens":10}"#,
            )
            .unwrap(),
        )
        .unwrap();
    assert_eq!(reply.get("ok"), &Json::Bool(true), "{reply}");
    assert!(reply.get("e2e_ms").as_f64().unwrap() > 0.0);
    assert!(reply.get("ttft_ms").as_f64().unwrap() > 0.0);
    assert_eq!(reply.get("generated").as_usize(), Some(10));
    teardown(server, door);
}

#[test]
fn streaming_frames_in_order() {
    let (server, door) = boot(1);
    let mut client = Client::connect(server.addr).unwrap();
    client
        .send(
            &Json::parse(
                r#"{"op":"generate","task":"chat","input_len":64,
                    "max_tokens":8,"stream":true}"#,
            )
            .unwrap(),
        )
        .unwrap();
    let first = client.next_line().unwrap();
    assert_eq!(first.get("event").as_str(), Some("admitted"), "{first}");
    assert!(first.get("queue_ms").as_f64().unwrap() >= 0.0);
    let id = first.get("id").as_usize().unwrap();
    let mut tokens = 0usize;
    let done = loop {
        let frame = client.next_line().unwrap();
        match frame.get("event").as_str() {
            Some("token") => {
                assert_eq!(frame.get("id").as_usize(), Some(id));
                assert_eq!(
                    frame.get("index").as_usize(),
                    Some(tokens),
                    "token indices must be sequential"
                );
                assert!(frame.get("t_ms").as_f64().unwrap() >= 0.0);
                tokens += 1;
            }
            Some("done") => break frame,
            other => panic!("unexpected frame {other:?}: {frame}"),
        }
    };
    assert_eq!(done.get("ok"), &Json::Bool(true), "{done}");
    assert_eq!(done.get("id").as_usize(), Some(id));
    let generated = done.get("generated").as_usize().unwrap();
    assert_eq!(
        tokens, generated,
        "one token frame per generated token"
    );
    assert_eq!(generated, 8);
    teardown(server, door);
}

#[test]
fn malformed_requests_rejected() {
    let (server, door) = boot(1);
    let mut client = Client::connect(server.addr).unwrap();
    // not an object with an op
    let reply = client.call(&Json::str("not an op")).unwrap();
    assert_eq!(reply.get("ok"), &Json::Bool(false));
    assert_eq!(reply.get("code").as_i64(), Some(400));
    // missing fields
    let reply = client
        .call(&Json::parse(r#"{"op":"generate"}"#).unwrap())
        .unwrap();
    assert_eq!(reply.get("ok"), &Json::Bool(false));
    // unknown op
    let reply = client
        .call(&Json::parse(r#"{"op":"fly"}"#).unwrap())
        .unwrap();
    assert_eq!(reply.get("ok"), &Json::Bool(false));
    // oversized request — rejected by the door before any queue
    let reply = client
        .call(
            &Json::parse(
                r#"{"op":"generate","input_len":999999,"max_tokens":10}"#,
            )
            .unwrap(),
        )
        .unwrap();
    assert_eq!(reply.get("ok"), &Json::Bool(false));
    assert_eq!(reply.get("code").as_i64(), Some(400));
    assert_eq!(door.door_stats().accepted, 0);
    teardown(server, door);
}

#[test]
fn stats_accumulate() {
    let (server, door) = boot(2);
    let mut a = Client::connect(server.addr).unwrap();
    let mut b = Client::connect(server.addr).unwrap();
    for client in [&mut a, &mut b] {
        let reply = client
            .call(
                &Json::parse(
                    r#"{"op":"generate","task":"code","input_len":50,"max_tokens":5,
                        "slo":{"kind":"e2e","e2e_ms":60000}}"#,
                )
                .unwrap(),
            )
            .unwrap();
        assert_eq!(reply.get("ok"), &Json::Bool(true), "{reply}");
    }
    let stats = a.call(&Json::parse(r#"{"op":"stats"}"#).unwrap()).unwrap();
    assert_eq!(stats.get("served").as_usize(), Some(2));
    assert_eq!(stats.get("accepted").as_usize(), Some(2));
    assert_eq!(stats.get("failed").as_usize(), Some(0));
    assert!(stats.get("attainment").as_f64().unwrap() > 0.0);
    assert!(stats.get("e2e_ms").get("p50").as_f64().unwrap() > 0.0);
    teardown(server, door);
}

#[test]
fn concurrent_clients_all_served() {
    let (server, door) = boot(1);
    let addr = server.addr;
    let threads: Vec<_> = (0..4)
        .map(|_| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                c.call(
                    &Json::parse(
                        r#"{"op":"generate","task":"chat","input_len":80,"max_tokens":6}"#,
                    )
                    .unwrap(),
                )
                .unwrap()
            })
        })
        .collect();
    for t in threads {
        let reply = t.join().unwrap();
        assert_eq!(reply.get("ok"), &Json::Bool(true), "{reply}");
        assert_eq!(reply.get("generated").as_usize(), Some(6));
    }
    assert!(door.wait_drained(30_000));
    assert_eq!(door.served(), 4);
    assert_eq!(door.door_stats().accepted, 4);
    teardown(server, door);
}

#[test]
fn shutdown_op_stops_reactor() {
    let (mut server, door) = boot(1);
    let mut client = Client::connect(server.addr).unwrap();
    let reply = client
        .call(&Json::parse(r#"{"op":"shutdown"}"#).unwrap())
        .unwrap();
    assert_eq!(reply.get("ok"), &Json::Bool(true));
    // the stop flag is set before the reply is flushed
    assert!(server.stopped());
    server.stop(); // joins the reactor thread
    door.shutdown();
}
