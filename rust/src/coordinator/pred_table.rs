//! Per-wave prediction table: the first layer of the SA hot-path
//! optimisation.
//!
//! The simulated-annealing search evaluates ~10⁴ candidate schedules per
//! scheduling decision, and every full evaluation used to call
//! [`LatencyPredictor::predict`] once per job — redoing the same Eq. 14–19
//! arithmetic for the same `(job, batch_size)` pair thousands of times.
//! A wave's job set and the batch-size domain (`1..=max_batch`) are fixed
//! for the whole search, so [`PredTable`] precomputes every
//! `(job, batch_size)` prediction once, turning all predictor calls inside
//! the search into a single indexed load.
//!
//! Entries are stored exactly as [`LatencyPredictor::predict`] returned
//! them, so table lookups are bit-identical to direct predictor calls —
//! the property the incremental evaluator's equivalence guarantee
//! ([`crate::coordinator::objective::IncrementalEval`]) rests on.
//!
//! Alongside the latency entries, the table precomputes each job's
//! **KV-block footprint** (prompt + predicted decode growth, rounded to
//! blocks — see [`KvConfig::job_blocks`]). Planned batches are static
//! (Eq. 10): the engine reserves a job's full input + output KV up front,
//! so the footprint is one number per job, independent of batch size, and
//! a batch's reserve-model occupancy is the plain sum over its members —
//! what the incremental evaluator maintains per batch (the phase-aware
//! model recomputes batch peaks from the raw job lengths instead; see
//! [`crate::coordinator::kv::KvPhaseModel`]).
//!
//! The table also carries each job's **arrival time** (the per-job
//! `arrival_ms` column of the
//! [`crate::coordinator::objective::TimelineOrigin`] timeline). Closed
//! waves leave the column at 0.0 — bit-identical to the pre-timeline
//! evaluation; the online controller fills it via [`PredTable::extend_at`]
//! and the column survives [`PredTable::compact`] like every other row.

use crate::coordinator::kv::KvConfig;
use crate::coordinator::objective::Job;
use crate::coordinator::predictor::{LatencyPredictor, PredictedLatency};

/// Dense `(job, batch_size)` → predicted-latency table plus per-job
/// KV-block footprints and arrival times.
///
/// Layout: row-major by job, `max_batch` entries per job, batch sizes
/// `1..=max_batch` (index `job * max_batch + batch - 1`).
#[derive(Debug, Clone)]
pub struct PredTable {
    n: usize,
    max_batch: usize,
    block_tokens: usize,
    /// Quantile-reservation multiplier the footprints were computed at
    /// ([`KvConfig::lo_mult`]); 1.0 for the exact (pre-quantile) column.
    lo_mult: f64,
    /// Per-block swap transfer time captured from the build-time
    /// [`KvConfig::swap_ms_per_block`]; 0.0 when the pool has no modeled
    /// swap link (then [`PredTable::swap_cost_ms`] is identically 0).
    swap_ms_per_block: f64,
    entries: Vec<PredictedLatency>,
    /// Per-job KV footprint in blocks (index = job).
    kv_blocks: Vec<u64>,
    /// Per-job arrival time (ms) on the wave timeline (index = job);
    /// 0.0 for closed waves.
    arrival_ms: Vec<f64>,
    /// Chunked-prefill chunk size the `chunk_ms` column was computed at;
    /// 0 = chunking off (the column then holds solo whole-prompt prefill
    /// and the evaluators never read it).
    chunk_tokens: usize,
    /// Per-job total chunked prefill time (ms, index = job):
    /// [`LatencyPredictor::chunked_prefill_ms`] at `chunk_tokens`.
    chunk_ms: Vec<f64>,
}

impl PredTable {
    /// Precompute predictions for every `(job, batch_size ≤ max_batch)`
    /// pair. O(N · max_batch) predictor calls, done once per wave.
    /// KV footprints use the default block granularity
    /// ([`crate::coordinator::kv::DEFAULT_BLOCK_TOKENS`]); use
    /// [`PredTable::build_kv`] when the pool geometry matters.
    pub fn build(
        jobs: &[Job],
        predictor: &LatencyPredictor,
        max_batch: usize,
    ) -> PredTable {
        PredTable::build_kv(jobs, predictor, max_batch, &KvConfig::UNLIMITED)
    }

    /// [`PredTable::build`] with an explicit KV configuration: footprints
    /// are rounded at `kv.block_tokens` granularity so the search's
    /// occupancy sums match the engine allocator's accounting exactly.
    pub fn build_kv(
        jobs: &[Job],
        predictor: &LatencyPredictor,
        max_batch: usize,
        kv: &KvConfig,
    ) -> PredTable {
        PredTable::build_kv_chunked(jobs, predictor, max_batch, kv, 0)
    }

    /// [`PredTable::build_kv`] with a chunked-prefill chunk size: the
    /// per-job `chunk_ms` column is computed at `chunk_tokens`
    /// ([`LatencyPredictor::chunked_prefill_ms`]). `chunk_tokens == 0`
    /// (chunking off) leaves every other column bit-identical to
    /// [`PredTable::build_kv`] and the evaluators never read `chunk_ms`.
    pub fn build_kv_chunked(
        jobs: &[Job],
        predictor: &LatencyPredictor,
        max_batch: usize,
        kv: &KvConfig,
        chunk_tokens: usize,
    ) -> PredTable {
        let max_batch = max_batch.max(1);
        let mut entries = Vec::with_capacity(jobs.len() * max_batch);
        let mut kv_blocks = Vec::with_capacity(jobs.len());
        let mut chunk_ms = Vec::with_capacity(jobs.len());
        for job in jobs {
            for b in 1..=max_batch {
                entries.push(predictor.predict(b, job.input_len, job.output_len));
            }
            kv_blocks.push(kv.job_blocks(job.input_len, job.output_len));
            chunk_ms
                .push(predictor.chunked_prefill_ms(job.input_len, chunk_tokens));
        }
        PredTable {
            n: jobs.len(),
            max_batch,
            block_tokens: kv.block_tokens,
            lo_mult: kv.lo_mult,
            swap_ms_per_block: kv.swap_ms_per_block(),
            entries,
            kv_blocks,
            arrival_ms: vec![0.0; jobs.len()],
            chunk_tokens,
            chunk_ms,
        }
    }

    /// Grow the table in place with predictions for newly admitted jobs
    /// (online wave admission): O(new · max_batch) predictor calls, no
    /// recomputation of existing rows. Appended entries are laid out
    /// exactly as [`PredTable::build`] would have placed them, so a table
    /// built empty and grown job-batch-by-job-batch is bit-identical to a
    /// table built over the full job set at once. Arrival times of the new
    /// rows are 0.0 (closed-wave timeline); use [`PredTable::extend_at`]
    /// to record real arrivals.
    pub fn extend(&mut self, new_jobs: &[Job], predictor: &LatencyPredictor) {
        self.extend_inner(new_jobs, predictor, None);
    }

    /// [`PredTable::extend`] with the new jobs' arrival times (ms), kept
    /// in the per-job `arrival_ms` column the timeline evaluators read.
    /// `arrivals.len()` must equal `new_jobs.len()`.
    pub fn extend_at(
        &mut self,
        new_jobs: &[Job],
        predictor: &LatencyPredictor,
        arrivals: &[f64],
    ) {
        assert_eq!(
            new_jobs.len(),
            arrivals.len(),
            "one arrival per admitted job"
        );
        self.extend_inner(new_jobs, predictor, Some(arrivals));
    }

    fn extend_inner(
        &mut self,
        new_jobs: &[Job],
        predictor: &LatencyPredictor,
        arrivals: Option<&[f64]>,
    ) {
        self.entries.reserve(new_jobs.len() * self.max_batch);
        let kv = KvConfig {
            block_tokens: self.block_tokens,
            lo_mult: self.lo_mult,
            ..KvConfig::UNLIMITED
        };
        for (i, job) in new_jobs.iter().enumerate() {
            for b in 1..=self.max_batch {
                self.entries.push(predictor.predict(
                    b,
                    job.input_len,
                    job.output_len,
                ));
            }
            self.kv_blocks.push(kv.job_blocks(job.input_len, job.output_len));
            self.arrival_ms.push(arrivals.map_or(0.0, |a| a[i]));
            self.chunk_ms.push(
                predictor.chunked_prefill_ms(job.input_len, self.chunk_tokens),
            );
        }
        self.n += new_jobs.len();
    }

    /// Overwrite the whole arrival column (one entry per job). Used by
    /// the closed-wave search to mirror a timeline evaluator's arrivals
    /// into the table it just built, so the incremental and full
    /// evaluations stay bit-identical.
    pub fn set_arrivals(&mut self, arrivals: &[f64]) {
        assert_eq!(arrivals.len(), self.n, "one arrival per job");
        self.arrival_ms.clear();
        self.arrival_ms.extend_from_slice(arrivals);
    }

    /// Drop the rows of jobs whose `keep[job]` is false (dispatched-prefix
    /// compaction in [`crate::coordinator::online::WaveController`]): pure
    /// memmove, no predictor calls. Remaining rows keep their relative
    /// order, so job index `j` maps to `keep[..j].count(true)` afterwards.
    pub fn compact(&mut self, keep: &[bool]) {
        assert_eq!(keep.len(), self.n, "keep mask does not cover the table");
        let mut w = 0usize;
        for (j, &k) in keep.iter().enumerate() {
            if k {
                if w != j {
                    let (dst, src) = (w * self.max_batch, j * self.max_batch);
                    for b in 0..self.max_batch {
                        self.entries[dst + b] = self.entries[src + b];
                    }
                    self.kv_blocks[w] = self.kv_blocks[j];
                    self.arrival_ms[w] = self.arrival_ms[j];
                    self.chunk_ms[w] = self.chunk_ms[j];
                }
                w += 1;
            }
        }
        self.entries.truncate(w * self.max_batch);
        self.kv_blocks.truncate(w);
        self.arrival_ms.truncate(w);
        self.chunk_ms.truncate(w);
        self.n = w;
    }

    /// Look up the prediction for `job` at `batch` (1-based, ≤ max_batch).
    #[inline]
    pub fn get(&self, job: usize, batch: usize) -> PredictedLatency {
        debug_assert!(batch >= 1 && batch <= self.max_batch, "batch {batch}");
        self.entries[job * self.max_batch + batch - 1]
    }

    /// Predicted solo (batch size 1) execution e2e — the sort key for
    /// Algorithm 1's second starting solution.
    #[inline]
    pub fn solo_exec_ms(&self, job: usize) -> f64 {
        self.get(job, 1).exec_ms
    }

    /// Predicted execution time of a batch made of `members` — the max of
    /// each member's exec at the batch's size (Eq. 11's inner max). This
    /// is the dispatch window the deadline-adaptive replan budget races
    /// against ([`crate::coordinator::online::OnlineOpts::adaptive_budget`]).
    pub fn batch_exec_max_ms(&self, members: &[usize]) -> f64 {
        let bsize = members.len();
        let mut bmax = 0.0f64;
        for &j in members {
            let e = self.get(j, bsize).exec_ms;
            if e > bmax {
                bmax = e;
            }
        }
        bmax
    }

    /// KV footprint of `job` in blocks (prompt + predicted output).
    #[inline]
    pub fn kv_blocks(&self, job: usize) -> u64 {
        self.kv_blocks[job]
    }

    /// All per-job KV footprints (index = job) — the move generator's
    /// veto reads this slice directly.
    #[inline]
    pub fn kv_blocks_all(&self) -> &[u64] {
        &self.kv_blocks
    }

    /// One-direction swap transfer time for `job`'s whole KV footprint
    /// (ms): `kv_blocks(job) × swap_ms_per_block` at the build-time pool
    /// geometry. 0 when no swap link was configured — the objective then
    /// never prices preemption. A suspend/resume round trip costs twice
    /// this (out + in), matching the engine's accounting
    /// ([`crate::engine::sim::PreemptMode::Swap`]).
    #[inline]
    pub fn swap_cost_ms(&self, job: usize) -> f64 {
        self.kv_blocks[job] as f64 * self.swap_ms_per_block
    }

    /// Arrival time of `job` (ms) on the wave timeline; 0.0 unless set by
    /// [`PredTable::extend_at`] / [`PredTable::set_arrivals`].
    #[inline]
    pub fn arrival_ms(&self, job: usize) -> f64 {
        self.arrival_ms[job]
    }

    /// The whole arrival column (index = job) — the timeline evaluators
    /// borrow this slice directly.
    #[inline]
    pub fn arrivals_all(&self) -> &[f64] {
        &self.arrival_ms
    }

    /// Total chunked prefill time of `job` (ms) at the table's
    /// `chunk_tokens`; solo whole-prompt prefill when chunking is off.
    #[inline]
    pub fn chunk_ms(&self, job: usize) -> f64 {
        self.chunk_ms[job]
    }

    /// Chunked-prefill chunk size the `chunk_ms` column was computed at
    /// (0 = chunking off).
    pub fn chunk_tokens(&self) -> usize {
        self.chunk_tokens
    }

    /// Block granularity the footprints were rounded at.
    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Quantile-reservation multiplier the footprints were computed at
    /// (1.0 unless built with a [`KvConfig::lo_mult`] above one).
    pub fn lo_mult(&self) -> f64 {
        self.lo_mult
    }

    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Slo;
    use crate::util::rng::Rng;

    #[test]
    fn table_matches_direct_predictor_calls() {
        let pred = LatencyPredictor::paper_table2();
        let mut rng = Rng::new(3);
        let jobs: Vec<Job> = (0..17)
            .map(|i| Job {
                req_idx: i,
                input_len: 1 + rng.below(2000),
                output_len: rng.below(500),
                slo: Slo::E2e { e2e_ms: 1e9 },
            })
            .collect();
        let table = PredTable::build(&jobs, &pred, 6);
        assert_eq!(table.len(), 17);
        assert_eq!(table.max_batch(), 6);
        for (j, job) in jobs.iter().enumerate() {
            for b in 1..=6 {
                let direct = pred.predict(b, job.input_len, job.output_len);
                // bit-identical, not merely close
                assert_eq!(table.get(j, b), direct, "job {j} batch {b}");
            }
            assert_eq!(
                table.solo_exec_ms(j),
                pred.predict(1, job.input_len, job.output_len).exec_ms
            );
        }
    }

    #[test]
    fn batch_exec_max_is_the_member_max_at_the_batch_size() {
        let pred = LatencyPredictor::paper_table2();
        let mut rng = Rng::new(11);
        let jobs: Vec<Job> = (0..9)
            .map(|i| Job {
                req_idx: i,
                input_len: 1 + rng.below(1500),
                output_len: rng.below(300),
                slo: Slo::E2e { e2e_ms: 1e9 },
            })
            .collect();
        let table = PredTable::build(&jobs, &pred, 4);
        for members in [&[2usize][..], &[0, 3], &[1, 4, 7], &[5, 6, 8, 0]] {
            let expect = members
                .iter()
                .map(|&j| table.get(j, members.len()).exec_ms)
                .fold(0.0f64, f64::max);
            assert_eq!(table.batch_exec_max_ms(members), expect);
        }
    }

    #[test]
    fn zero_max_batch_clamped_to_one() {
        let pred = LatencyPredictor::paper_table2();
        let jobs = vec![Job {
            req_idx: 0,
            input_len: 100,
            output_len: 10,
            slo: Slo::E2e { e2e_ms: 1e9 },
        }];
        let table = PredTable::build(&jobs, &pred, 0);
        assert_eq!(table.max_batch(), 1);
        assert!(table.get(0, 1).exec_ms > 0.0);
    }

    #[test]
    fn grown_table_is_bit_identical_to_rebuilt_table() {
        let pred = LatencyPredictor::paper_table2();
        let mut rng = Rng::new(5);
        let jobs: Vec<Job> = (0..13)
            .map(|i| Job {
                req_idx: i,
                input_len: 1 + rng.below(1800),
                output_len: rng.below(400),
                slo: Slo::E2e { e2e_ms: 1e9 },
            })
            .collect();
        // grow from empty in uneven admission chunks
        let mut grown = PredTable::build(&[], &pred, 4);
        grown.extend(&jobs[..1], &pred);
        grown.extend(&jobs[1..6], &pred);
        grown.extend(&jobs[6..], &pred);
        let rebuilt = PredTable::build(&jobs, &pred, 4);
        assert_eq!(grown.len(), rebuilt.len());
        for j in 0..jobs.len() {
            for b in 1..=4 {
                assert_eq!(grown.get(j, b), rebuilt.get(j, b), "{j} {b}");
            }
        }
    }

    #[test]
    fn empty_jobs() {
        let pred = LatencyPredictor::paper_table2();
        let table = PredTable::build(&[], &pred, 4);
        assert!(table.is_empty());
    }

    #[test]
    fn kv_footprints_match_config_math() {
        use crate::coordinator::kv::KvConfig;
        let pred = LatencyPredictor::paper_table2();
        let jobs = vec![
            Job { req_idx: 0, input_len: 30, output_len: 3, slo: Slo::E2e { e2e_ms: 1e9 } },
            Job { req_idx: 1, input_len: 16, output_len: 0, slo: Slo::E2e { e2e_ms: 1e9 } },
        ];
        let kv = KvConfig::hard(100);
        let table = PredTable::build_kv(&jobs, &pred, 3, &kv);
        assert_eq!(table.kv_blocks(0), 3); // 33 tokens -> 3 blocks of 16
        assert_eq!(table.kv_blocks(1), 1);
        assert_eq!(table.kv_blocks_all(), &[3, 1]);
        assert_eq!(table.block_tokens(), 16);
        // extend keeps the same granularity
        let mut grown = table.clone();
        grown.extend(
            &[Job { req_idx: 2, input_len: 17, output_len: 0, slo: Slo::E2e { e2e_ms: 1e9 } }],
            &pred,
        );
        assert_eq!(grown.kv_blocks(2), 2);
    }

    #[test]
    fn swap_cost_column_follows_pool_geometry() {
        use crate::coordinator::kv::KvConfig;
        let pred = LatencyPredictor::paper_table2();
        let jobs = vec![
            Job { req_idx: 0, input_len: 30, output_len: 3, slo: Slo::E2e { e2e_ms: 1e9 } },
            Job { req_idx: 1, input_len: 16, output_len: 0, slo: Slo::E2e { e2e_ms: 1e9 } },
        ];
        // 8 MB blocks over an 8 GB/s link: 1 ms per block
        let kv = KvConfig::hard(100).with_swap(8.0, 8.0, 64);
        let table = PredTable::build_kv(&jobs, &pred, 3, &kv);
        assert_eq!(table.swap_cost_ms(0), 3.0); // 3 blocks × 1 ms
        assert_eq!(table.swap_cost_ms(1), 1.0);
        // no link configured -> the column is identically zero
        let plain = PredTable::build_kv(&jobs, &pred, 3, &KvConfig::hard(100));
        assert_eq!(plain.swap_cost_ms(0), 0.0);
        assert_eq!(plain.swap_cost_ms(1), 0.0);
    }

    #[test]
    fn quantile_column_survives_extend() {
        use crate::coordinator::kv::KvConfig;
        let pred = LatencyPredictor::paper_table2();
        let job = |i: usize| Job {
            req_idx: i,
            input_len: 30,
            output_len: 10,
            slo: Slo::E2e { e2e_ms: 1e9 },
        };
        let kv = KvConfig::hard(100).with_lo_mult(2.0);
        let mut table = PredTable::build_kv(&[job(0)], &pred, 3, &kv);
        assert_eq!(table.lo_mult(), 2.0);
        // 30 + 2×10 = 50 tokens -> 4 blocks of 16
        assert_eq!(table.kv_blocks(0), 4);
        // extend must keep charging the same conservative column
        table.extend(&[job(1)], &pred);
        assert_eq!(table.kv_blocks(1), 4);
        assert_eq!(table.kv_blocks(1), kv.job_blocks(30, 10));
        // the default column is the exact one
        let plain = PredTable::build(&[job(0)], &pred, 3);
        assert_eq!(plain.lo_mult(), 1.0);
        assert_eq!(plain.kv_blocks(0), 3); // 40 tokens -> 3 blocks
    }

    #[test]
    fn arrival_column_survives_extend_and_compact() {
        let pred = LatencyPredictor::paper_table2();
        let job = |i: usize| Job {
            req_idx: i,
            input_len: 50 + i,
            output_len: 5,
            slo: Slo::E2e { e2e_ms: 1e9 },
        };
        let jobs: Vec<Job> = (0..6).map(job).collect();
        let mut table = PredTable::build(&jobs[..2], &pred, 3);
        // closed-wave rows default to t = 0
        assert_eq!(table.arrivals_all(), &[0.0, 0.0]);
        table.extend_at(&jobs[2..4], &pred, &[100.0, 250.0]);
        table.extend(&jobs[4..5], &pred); // legacy extend keeps 0.0
        table.extend_at(&jobs[5..6], &pred, &[900.0]);
        assert_eq!(
            table.arrivals_all(),
            &[0.0, 0.0, 100.0, 250.0, 0.0, 900.0]
        );
        assert_eq!(table.arrival_ms(3), 250.0);
        // compaction keeps the surviving rows' arrivals aligned
        table.compact(&[false, true, true, false, true, true]);
        assert_eq!(table.arrivals_all(), &[0.0, 100.0, 0.0, 900.0]);
        // entries stayed aligned with their jobs too
        assert_eq!(
            table.get(1, 2),
            pred.predict(2, jobs[2].input_len, jobs[2].output_len)
        );
        // set_arrivals overwrites the whole column
        table.set_arrivals(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(table.arrivals_all(), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn chunk_column_survives_extend_and_compact() {
        let pred = LatencyPredictor::paper_table2();
        let job = |i: usize, input: usize| Job {
            req_idx: i,
            input_len: input,
            output_len: 5,
            slo: Slo::E2e { e2e_ms: 1e9 },
        };
        let jobs = vec![job(0, 1000), job(1, 64), job(2, 700)];
        let mut table = PredTable::build_kv_chunked(
            &jobs,
            &pred,
            3,
            &KvConfig::UNLIMITED,
            256,
        );
        assert_eq!(table.chunk_tokens(), 256);
        for (j, jb) in jobs.iter().enumerate() {
            assert_eq!(
                table.chunk_ms(j).to_bits(),
                pred.chunked_prefill_ms(jb.input_len, 256).to_bits()
            );
        }
        // extend fills the column at the table's chunk size
        table.extend(&[job(3, 900)], &pred);
        assert_eq!(
            table.chunk_ms(3).to_bits(),
            pred.chunked_prefill_ms(900, 256).to_bits()
        );
        // compact keeps the surviving rows aligned
        table.compact(&[false, true, false, true]);
        assert_eq!(
            table.chunk_ms(0).to_bits(),
            pred.chunked_prefill_ms(64, 256).to_bits()
        );
        assert_eq!(
            table.chunk_ms(1).to_bits(),
            pred.chunked_prefill_ms(900, 256).to_bits()
        );
        // chunking off: the column is solo whole-prompt prefill and the
        // latency entries are bit-identical to the unchunked build
        let plain = PredTable::build(&jobs, &pred, 3);
        assert_eq!(plain.chunk_tokens(), 0);
        assert_eq!(
            plain.chunk_ms(0).to_bits(),
            pred.prefill_ms(1, 1000).to_bits()
        );
    }

    #[test]
    fn compact_drops_rows_and_preserves_the_rest() {
        let pred = LatencyPredictor::paper_table2();
        let mut rng = Rng::new(11);
        let jobs: Vec<Job> = (0..9)
            .map(|i| Job {
                req_idx: i,
                input_len: 1 + rng.below(1500),
                output_len: rng.below(300),
                slo: Slo::E2e { e2e_ms: 1e9 },
            })
            .collect();
        let mut table = PredTable::build(&jobs, &pred, 3);
        let keep = [true, false, false, true, true, false, true, true, false];
        table.compact(&keep);
        let kept: Vec<&Job> =
            jobs.iter().zip(&keep).filter(|(_, &k)| k).map(|(j, _)| j).collect();
        assert_eq!(table.len(), kept.len());
        for (new_j, job) in kept.iter().enumerate() {
            for b in 1..=3 {
                assert_eq!(
                    table.get(new_j, b),
                    pred.predict(b, job.input_len, job.output_len),
                    "job {new_j} batch {b}"
                );
            }
        }
        // compacting everything away leaves an empty, still-usable table
        let mask = vec![false; table.len()];
        table.compact(&mask);
        assert!(table.is_empty());
    }
}
