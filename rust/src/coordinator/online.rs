//! Online wave admission: warm-started SA replanning over arrival streams.
//!
//! The paper plans one **closed wave** at a time: every request is present
//! before Algorithm 1 runs, and the plan executes to completion. A serving
//! front-end under live multi-SLO traffic instead sees a *stream* of
//! arrivals that must be admitted into an in-flight plan. This module is
//! the batch-to-streaming bridge:
//!
//! * [`WaveController`] owns the growing wave of one instance. On each
//!   admission it extends the per-wave prediction table **in place**
//!   ([`PredTable::extend`] — no recomputation of existing rows), freezes
//!   the already-dispatched prefix of the current plan, and re-runs the SA
//!   search **warm-started from the current best order** with frozen-prefix
//!   move masking
//!   ([`priority_mapping_warm`]).
//! * [`run_online`] is the event loop gluing a controller to an engine:
//!   admit everything that has arrived, dispatch the next planned batch,
//!   let the (virtual) clock advance, repeat — so dispatch and replanning
//!   interleave exactly as they would on a live server.
//! * [`run_online_fleet`] drives one controller per instance with the
//!   round-robin arrival split a fleet front-end applies; instance clocks
//!   are independent, so per-instance runs compose without a global event
//!   queue.
//!
//! **Equivalence guarantee** (tests/online_admission.rs): when every
//! request arrives at t = 0 the controller admits the whole wave in one
//! step with nothing frozen and no warm seed, and
//! [`priority_mapping_warm`] then replays the closed-wave
//! [`crate::coordinator::priority::annealing::priority_mapping`] bit for
//! bit — same seeds, same RNG stream, same plan and objective. Online
//! admission strictly generalizes the paper's wave scheduling.
//!
//! **Objective under a frozen prefix**: the controller keeps dispatched
//! jobs in the evaluated schedule. Their e2e contributions are constants
//! with respect to every masked move, but the frozen batch maxima still
//! feed the suffix's entry wait — so a request stuck behind already
//! dispatched work is correctly modelled as closer to its SLO bound.
//!
//! **Arrival-aware timeline** ([`WaveController::admit_at`],
//! [`OnlineOpts::arrival_aware`]): by default the predicted objective
//! evaluates on the closed-wave timeline (every job at t = 0 — the
//! pre-timeline behaviour, bit for bit). When the event loop admits with
//! real arrival times, the evaluation runs on a
//! [`TimelineOrigin`] instead: batch `k` starts at
//! `max(end of batch k−1, latest member arrival)`, so engine idle gaps
//! between arrival waves and per-job arrival offsets both flow into every
//! entry wait, and each job's predicted wait/e2e is measured from its own
//! arrival — the same accounting the measured [`Completion`]s use. The
//! remaining predicted-vs-executed gap is pure latency-model error (and
//! exactly zero when the model is exact — see
//! `tests/timeline_fidelity.rs`).
//!
//! **KV admission** ([`SaParams::kv`], Eq. 20): with a binding pool the
//! controller refuses jobs that could never execute (footprint beyond the
//! pool — a hard error), packs newly admitted jobs into seed batches that
//! respect the pool, and exposes [`WaveController::saturated`] so the
//! event loop can defer admissions while a full pool's worth of planned
//! work is still undispatched — the deferred jobs are admitted at a later
//! replan, once dispatching has drained the backlog.
//!
//! **Drift reconciliation** ([`WaveController::reconcile`],
//! [`OnlineOpts::replan_drift_ms`]): predictions err — under
//! output-length divergence ([`crate::engine::sim::DivergenceModel`])
//! systematically so. After each dispatched batch executes, `reconcile`
//! compares the engine's **measured** clock against the predicted end of
//! the dispatched prefix and records the signed drift (plus per-request
//! output-length divergence from the batch's completions). Reconciling
//! is pure bookkeeping — no RNG, no plan change — so it never perturbs a
//! run. When the event loop is given a positive
//! [`OnlineOpts::replan_drift_ms`] and the |drift| crosses it,
//! [`WaveController::replan_from_drift`] shifts the timeline origin to
//! the measured time (compacting the dispatched prefix — measured work
//! must not be re-predicted) and re-runs the warm search over the live
//! suffix, so subsequent scheduling decisions price waits from reality
//! instead of a stale prediction. The default threshold of 0 disables
//! the loop entirely — the historical behaviour, bit for bit.
//!
//! **Prefix compaction** ([`WaveController::with_compaction`]): by default
//! the job set and prediction table grow for the lifetime of the
//! controller — on long traces, without bound. Compaction drops fully
//! dispatched batches at the next admission: their predicted end time is
//! folded into the timeline origin ([`TimelineOrigin::t0`] — the scalar
//! base-wait offset of the pre-timeline controller is its t = 0
//! degenerate case) so the surviving suffix sees identical entry waits,
//! and the prediction table rows are dropped by memmove (no predictor
//! recomputation). Dispatched
//! jobs then no longer contribute their (constant) e2e terms to `G`, so
//! the replanned objective ranks suffixes slightly differently than the
//! non-compacted controller — compaction is opt-in, and the default
//! controller remains bit-identical to the pre-compaction behaviour.
//!
//! **Deadline-adaptive budgets**
//! ([`WaveController::with_adaptive_budget`],
//! [`OnlineOpts::adaptive_budget`]): a replan is only free while the
//! engine is busy executing the batch dispatched ahead of it — a fixed
//! `iters_per_temp` either wastes that window or overruns it. With
//! adaptive budgets on, the controller keeps an EWMA of measured replan
//! wall time per SA unit (one iteration at one temperature on one chain)
//! and sizes each replan's `iters_per_temp` so the predicted search time
//! fills the predicted execution window of the next batch to dispatch,
//! clamped to `[4, 16 × configured]`. The first replan (no measurement
//! yet) and replans with no planned next batch run at the configured
//! budget. Off by default — the fixed-budget behaviour, bit for bit.

use std::collections::{HashSet, VecDeque};

use anyhow::{bail, Result};

use crate::coordinator::kv::{self, KvPhaseModel};
use crate::coordinator::objective::{
    Eval, Evaluator, Job, Schedule, TimelineOrigin,
};
use crate::coordinator::policies::{slack_key, slo_deadline_ms};
use crate::coordinator::pred_table::PredTable;
use crate::coordinator::predictor::LatencyPredictor;
use crate::coordinator::priority::annealing::{
    priority_mapping_warm, SaParams, SearchStats,
};
use crate::coordinator::request::{Completion, Request};
use crate::coordinator::scheduler::instance_seed;
use crate::engine::{Engine, EngineRequest};

/// How a replan seeds its search when arrivals are admitted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplanStrategy {
    /// Warm start: previous best order with the new jobs appended seeds the
    /// search (plus Algorithm 1's cold seeds while nothing is frozen).
    Warm,
    /// Cold restart at the same iteration budget: the learned suffix order
    /// is discarded and the search re-seeds from the frozen prefix plus the
    /// undispatched jobs in admission order (the ablation baseline the
    /// warm/cold comparison in `examples/online_serving.rs` reports).
    Cold,
}

impl ReplanStrategy {
    pub fn name(&self) -> &'static str {
        match self {
            ReplanStrategy::Warm => "warm",
            ReplanStrategy::Cold => "cold",
        }
    }
}

/// Controller-side diagnostics accumulated across a run.
#[derive(Debug, Clone, Copy, Default)]
pub struct OnlineStats {
    /// Jobs admitted into the wave.
    pub admitted: usize,
    /// Replans executed (one per non-empty admission).
    pub replans: usize,
    /// Total replanning wall time (ms): Σ per-replan
    /// [`SearchStats::overhead_ms`], the max across tempered chains since
    /// they run concurrently. What a dispatch actually waits for.
    pub replan_ms_total: f64,
    /// Total replanning CPU time (ms): Σ per-replan
    /// [`SearchStats::cpu_ms`] — wall plus the concurrent busy time of
    /// the extra tempered chains. Equals `replan_ms_total` at
    /// `chains == 1`. Fig. 11(B)-style overhead comparisons across chain
    /// counts must use this, not wall.
    pub replan_cpu_ms_total: f64,
    /// Replans that ran under a deadline-adaptive iteration budget
    /// ([`WaveController::with_adaptive_budget`]).
    pub budget_replans: usize,
    /// Σ wall-clock window (ms) allotted to the budgeted replans (the
    /// predicted dispatch gap each was sized to fit).
    pub budget_allotted_ms_total: f64,
    /// Σ measured wall time (ms) the budgeted replans actually spent.
    pub budget_spent_ms_total: f64,
    /// Total objective evaluations across all replans.
    pub sa_evals: usize,
    /// Batches dispatched (frozen).
    pub dispatched_batches: usize,
    /// Jobs dispatched.
    pub dispatched_jobs: usize,
    /// Replans triggered by timeline drift
    /// ([`WaveController::replan_from_drift`]); also counted in `replans`.
    pub drift_replans: usize,
    /// Largest |measured − predicted| prefix-end drift seen (ms).
    pub max_abs_drift_ms: f64,
    /// Completions reconciled so far.
    pub reconciled_jobs: usize,
    /// Σ |actual − predicted| output length over reconciled completions.
    pub lo_abs_divergence_sum: f64,
    /// Arrivals whose admission was deferred at least once because the
    /// controller was [`WaveController::saturated`] (each arrival counts
    /// once, however many retries it took). Queue-driven callers — the
    /// event loops here and the serving front door
    /// ([`crate::server::front`]) — report it via
    /// [`WaveController::note_deferrals`].
    pub deferrals: usize,
    /// Engine-side preemptions (mid-decode suspensions) observed across
    /// this run's dispatched batches: the delta of
    /// [`crate::engine::PreemptionStats::preemptions`] around each
    /// `run_batch`. Distinct from `deferrals` by construction — a
    /// deferral holds a request *out* of the wave before admission, a
    /// preemption suspends it *after* dispatch — so the two counters
    /// never alias one request event (the pre-split accounting folded
    /// both into `deferrals` and double-counted
    /// deferred → admitted → preempted requests).
    pub preemptions: usize,
    /// Requests this instance shed to a fleet peer
    /// ([`run_online_fleet_migrating`]); counted on the shedding (source)
    /// instance, once per moved request. Always 0 on single-instance
    /// fleets — there is no peer to steal work.
    pub migrations: usize,
}

impl OnlineStats {
    /// Mean replanning time (ms) per admission.
    pub fn avg_replan_ms(&self) -> f64 {
        if self.replans == 0 {
            0.0
        } else {
            self.replan_ms_total / self.replans as f64
        }
    }

    /// Mean replanning CPU time (ms) per admission (Σ across tempered
    /// chains; equals [`OnlineStats::avg_replan_ms`] at `chains == 1`).
    pub fn avg_replan_cpu_ms(&self) -> f64 {
        if self.replans == 0 {
            0.0
        } else {
            self.replan_cpu_ms_total / self.replans as f64
        }
    }

    /// Measured-over-allotted wall-time ratio of the budgeted replans
    /// (1.0 = replans exactly fill their predicted dispatch gaps; 0 when
    /// no replan was budgeted).
    pub fn budget_utilization(&self) -> f64 {
        if self.budget_allotted_ms_total > 0.0 {
            self.budget_spent_ms_total / self.budget_allotted_ms_total
        } else {
            0.0
        }
    }

    /// Mean |actual − predicted| output length over reconciled
    /// completions (tokens); 0 before anything was reconciled.
    pub fn avg_abs_lo_divergence(&self) -> f64 {
        if self.reconciled_jobs == 0 {
            0.0
        } else {
            self.lo_abs_divergence_sum / self.reconciled_jobs as f64
        }
    }
}

/// One dispatchable unit: the next undispatched batch of the plan.
#[derive(Debug, Clone)]
pub struct Dispatch {
    /// Batch index within the controller's plan.
    pub batch: usize,
    /// Scheduler job views; `req_idx` points into the caller's request
    /// slice, in the planned intra-batch order.
    pub jobs: Vec<Job>,
}

/// Online admission controller for one instance (module docs).
///
/// ```
/// use slo_serve::coordinator::objective::Job;
/// use slo_serve::coordinator::online::{ReplanStrategy, WaveController};
/// use slo_serve::coordinator::predictor::LatencyPredictor;
/// use slo_serve::coordinator::priority::annealing::SaParams;
/// use slo_serve::coordinator::request::Slo;
///
/// let predictor = LatencyPredictor::paper_table2();
/// let params = SaParams {
///     max_batch: 2,
///     t0: 50.0,
///     iters_per_temp: 5,
///     ..Default::default()
/// };
/// let mut ctl = WaveController::new(&predictor, params, ReplanStrategy::Warm);
/// let jobs: Vec<Job> = (0..4)
///     .map(|i| Job {
///         req_idx: i,
///         input_len: 100 + 10 * i,
///         output_len: 10,
///         slo: Slo::E2e { e2e_ms: 60_000.0 },
///     })
///     .collect();
/// // admit with per-job arrival times: the replanned objective evaluates
/// // on the arrival-aware timeline (use `admit` for the t = 0 timeline)
/// ctl.admit_at(&jobs, &[0.0, 0.0, 40.0, 90.0])?;
/// assert_eq!(ctl.plan().len(), 4);
/// let first = ctl.dispatch_next().expect("planned work to dispatch");
/// assert!(!first.jobs.is_empty());
/// assert_eq!(ctl.frozen_batches(), 1); // dispatched prefix is frozen
/// # Ok::<(), anyhow::Error>(())
/// ```
pub struct WaveController<'a> {
    predictor: &'a LatencyPredictor,
    params: SaParams,
    strategy: ReplanStrategy,
    /// All admitted, still-tracked jobs in admission order (indices are
    /// plan order ids; compaction drops dispatched ones).
    jobs: Vec<Job>,
    /// Grown in place on every admission — never rebuilt. Carries the
    /// per-job arrival column the timeline evaluation reads.
    table: PredTable,
    plan: Schedule,
    eval: Eval,
    /// Leading batches of `plan` already dispatched (frozen).
    frozen_batches: usize,
    /// Compact dispatched batches out of the wave at each admission
    /// (opt-in: changes the replanned objective — module docs).
    compact: bool,
    /// Timeline origin: when the engine is free for the first still-live
    /// batch ([`TimelineOrigin::t0`]). 0.0 until compaction folds a
    /// dispatched prefix's predicted end into it.
    t0_ms: f64,
    /// Jobs dropped by compaction so far.
    retired_jobs: usize,
    /// Latest measured-minus-predicted prefix-end drift (ms), recorded by
    /// [`WaveController::reconcile`]; reset to 0 by a drift replan.
    drift_ms: f64,
    /// Engine clock at the last reconcile — the measured timeline origin
    /// a drift replan shifts to.
    reconciled_now: Option<f64>,
    /// Incremental prefix-end fold (batches folded, positions covered,
    /// running end): the frozen prefix is append-only between
    /// compactions, so [`WaveController::reconcile`] folds only the
    /// batches frozen since the last call — O(new batch) per dispatch
    /// instead of O(prefix), which would go quadratic on long
    /// non-compacted traces. Reset whenever compaction rewrites the
    /// prefix. Bit-identical to the O(prefix)
    /// [`WaveController::predicted_prefix_end`] fold (same op order).
    fold_k: usize,
    fold_pos: usize,
    fold_end: f64,
    /// Size each replan's iteration budget to the next predicted dispatch
    /// gap ([`WaveController::with_adaptive_budget`]); off by default —
    /// the fixed-`iters_per_temp` behaviour, bit for bit.
    adaptive_budget: bool,
    /// EWMA of measured replan wall ms per SA *unit* (one iteration at
    /// one temperature on one chain); `None` until the first replan
    /// provides a measurement.
    ewma_ms_per_unit: Option<f64>,
    /// Request ids already counted in [`OnlineStats::deferrals`]
    /// ([`WaveController::note_deferral_of`]): a request that cycles
    /// defer → admit → defer (e.g. bounced back by a migration) counts
    /// once for the lifetime of the controller.
    deferred_ids: HashSet<u64>,
    stats: OnlineStats,
    /// Last replan's search stats (None before the first admission).
    last_search: Option<SearchStats>,
}

/// EWMA smoothing constant for the measured SA cost-per-unit estimate
/// driving deadline-adaptive budgets.
const BUDGET_EWMA_ALPHA: f64 = 0.3;
/// Adaptive-budget floor: a replan never drops below this many iterations
/// per temperature, however tight the predicted dispatch gap.
const BUDGET_MIN_ITERS: usize = 4;
/// Adaptive-budget ceiling: a replan never exceeds this multiple of the
/// configured `iters_per_temp`, however wide the gap.
const BUDGET_MAX_SCALE: usize = 16;

impl<'a> WaveController<'a> {
    pub fn new(
        predictor: &'a LatencyPredictor,
        params: SaParams,
        strategy: ReplanStrategy,
    ) -> Self {
        let max_batch = params.max_batch.max(1);
        WaveController {
            predictor,
            params,
            strategy,
            jobs: Vec::new(),
            table: PredTable::build_kv_chunked(
                &[],
                predictor,
                max_batch,
                &params.kv,
                params.chunk_tokens,
            ),
            plan: Schedule { order: vec![], batches: vec![] },
            eval: Eval::ZERO,
            frozen_batches: 0,
            compact: false,
            t0_ms: 0.0,
            retired_jobs: 0,
            drift_ms: 0.0,
            reconciled_now: None,
            fold_k: 0,
            fold_pos: 0,
            fold_end: 0.0,
            adaptive_budget: false,
            ewma_ms_per_unit: None,
            deferred_ids: HashSet::new(),
            stats: OnlineStats::default(),
            last_search: None,
        }
    }

    /// Enable dispatched-prefix compaction (ROADMAP follow-up: the job set
    /// and prediction table otherwise grow unboundedly on long traces).
    /// At each admission, fully dispatched batches are dropped from the
    /// wave: their predicted end is folded into the timeline origin so the
    /// suffix's predicted entry waits are unchanged, and their table rows
    /// are released. See the module docs for the objective-semantics
    /// caveat.
    pub fn with_compaction(mut self) -> Self {
        self.compact = true;
        self
    }

    /// Enable deadline-adaptive iteration budgets: each replan's
    /// `iters_per_temp` is sized so the predicted search wall time — an
    /// EWMA of measured ms per SA unit (iteration × temperature × chain)
    /// over past replans — fits the predicted execution time of the next
    /// batch to dispatch, clamped to
    /// `[BUDGET_MIN_ITERS, BUDGET_MAX_SCALE × iters_per_temp]`. Replans
    /// with no measurement yet (the first) or no planned next batch run
    /// at the configured budget. Off by default — the fixed-budget
    /// behaviour, bit for bit.
    pub fn with_adaptive_budget(mut self) -> Self {
        self.adaptive_budget = true;
        self
    }

    pub fn jobs(&self) -> &[Job] {
        &self.jobs
    }

    /// The current plan over all admitted jobs (frozen prefix included).
    pub fn plan(&self) -> &Schedule {
        &self.plan
    }

    /// Predicted evaluation of the current plan.
    pub fn eval(&self) -> Eval {
        self.eval
    }

    pub fn frozen_batches(&self) -> usize {
        self.frozen_batches
    }

    /// Number of leading plan positions that are frozen (dispatched).
    pub fn frozen_positions(&self) -> usize {
        self.plan.batches[..self.frozen_batches].iter().sum()
    }

    pub fn stats(&self) -> &OnlineStats {
        &self.stats
    }

    pub fn last_search(&self) -> Option<&SearchStats> {
        self.last_search.as_ref()
    }

    /// True when every planned batch has been dispatched.
    pub fn drained(&self) -> bool {
        self.frozen_batches == self.plan.batches.len()
    }

    /// Timeline origin of the live wave: predicted end of the
    /// compacted-away prefix (0 until compaction is enabled and something
    /// has been compacted).
    pub fn t0_ms(&self) -> f64 {
        self.t0_ms
    }

    /// Alias of [`WaveController::t0_ms`] kept for the pre-timeline name.
    pub fn base_wait_ms(&self) -> f64 {
        self.t0_ms
    }

    /// Per-job arrival times of the tracked wave (index = plan job id) —
    /// the table's arrival column; zeros unless admitted via
    /// [`WaveController::admit_at`].
    pub fn arrivals(&self) -> &[f64] {
        self.table.arrivals_all()
    }

    /// Jobs dropped from the wave by compaction so far.
    pub fn retired_jobs(&self) -> usize {
        self.retired_jobs
    }

    /// KV-block demand of the planned-but-undispatched suffix (Eq. 20):
    /// the footprint sum under [`KvPhaseModel::Reserve`], the sum of
    /// per-batch occupancy peaks under [`KvPhaseModel::Phased`] (each
    /// batch's peak bounds what it can pin at once, so a phased backlog
    /// saturates later — more admission on the same pool).
    pub fn undispatched_blocks(&self) -> u64 {
        let frozen_pos = self.frozen_positions();
        match self.params.kv.phase {
            KvPhaseModel::Reserve => self.plan.order[frozen_pos..]
                .iter()
                .map(|&j| self.table.kv_blocks(j))
                .sum(),
            KvPhaseModel::Phased => {
                let mut total = 0u64;
                let mut members: Vec<(usize, usize)> = Vec::new();
                for (k, start, size) in self.plan.batch_spans() {
                    if k < self.frozen_batches {
                        continue;
                    }
                    members.clear();
                    members.extend(
                        self.plan.order[start..start + size].iter().map(|&j| {
                            let job = &self.jobs[j];
                            (job.input_len, job.output_len)
                        }),
                    );
                    total +=
                        kv::phased_peak_blocks(&members, self.params.kv.block_tokens);
                }
                total
            }
        }
    }

    /// True when a binding KV pool is fully covered by undispatched work:
    /// admitting more now would plan beyond a pool's worth of backlog, so
    /// the event loop defers new arrivals to a later replan (module docs).
    /// A degenerate empty pool never reads as saturated — deferring on it
    /// would spin forever, while admitting surfaces
    /// [`WaveController::admit`]'s clear oversize error.
    pub fn saturated(&self) -> bool {
        self.params.kv.binding()
            && self.undispatched_blocks() >= self.params.kv.pool_blocks.max(1)
    }

    /// Record `n` arrivals newly deferred by saturation
    /// ([`OnlineStats::deferrals`]). The controller cannot see deferrals
    /// itself — the admission queue lives with the caller — so the event
    /// loops and the serving front door report them here, keeping the
    /// counter next to the rest of the admission diagnostics.
    pub fn note_deferrals(&mut self, n: usize) {
        self.stats.deferrals += n;
    }

    /// Record the saturation deferral of request `id`, counting it **at
    /// most once** for the lifetime of the controller however many
    /// defer → admit → defer cycles the request goes through (re-deferral
    /// after a drift replan re-saturated the backlog, or after a fleet
    /// migration bounced it to — and back from — a peer). Returns whether
    /// the deferral was newly counted. The bulk
    /// [`WaveController::note_deferrals`] path cannot dedupe; callers
    /// holding stable request ids should prefer this.
    pub fn note_deferral_of(&mut self, id: u64) -> bool {
        let first = self.deferred_ids.insert(id);
        if first {
            self.stats.deferrals += 1;
        }
        first
    }

    /// Accumulate engine-observed preemptions
    /// ([`OnlineStats::preemptions`]) — the event loops report the
    /// per-dispatch [`crate::engine::Engine::preemption_stats`] delta
    /// here, keeping it next to the admission diagnostics.
    pub fn note_preemptions(&mut self, n: usize) {
        self.stats.preemptions += n;
    }

    /// Accumulate requests shed to a fleet peer
    /// ([`OnlineStats::migrations`]).
    pub fn note_migrations(&mut self, n: usize) {
        self.stats.migrations += n;
    }

    /// Per-replan SA seed: the first replan uses the configured seed
    /// verbatim (the online-equals-offline equivalence), later replans
    /// derive fresh streams so repeated searches do not replay each other.
    fn replan_seed(&self) -> u64 {
        let r = self.stats.replans as u64;
        self.params
            .seed
            .wrapping_add(r.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Predicted wall-clock window (ms) this replan has before the engine
    /// needs its next plan: the predicted execution time (member exec max
    /// at the batch's size) of the next batch to dispatch — the replan
    /// overlaps that batch's execution, so finishing within it costs no
    /// engine idle time. `None` when nothing is planned or everything is
    /// dispatched (no gap to size against).
    fn next_dispatch_window_ms(&self) -> Option<f64> {
        if self.frozen_batches >= self.plan.batches.len() {
            return None;
        }
        let k = self.frozen_batches;
        let start: usize = self.plan.batches[..k].iter().sum();
        let bsize = self.plan.batches[k];
        Some(self.table.batch_exec_max_ms(&self.plan.order[start..start + bsize]))
    }

    /// Deadline-adaptive budget for the upcoming replan: `Some((window,
    /// iters))` when adaptive budgets are on, a cost estimate exists, and
    /// there is a next dispatch gap to size against — `iters` is the
    /// per-temperature budget whose predicted wall time fills `window`,
    /// clamped to `[BUDGET_MIN_ITERS, BUDGET_MAX_SCALE × configured]`.
    fn adaptive_window(&self) -> Option<(f64, usize)> {
        if !self.adaptive_budget {
            return None;
        }
        let cost = self.ewma_ms_per_unit?;
        let window = self.next_dispatch_window_ms()?;
        let levels = self.params.temp_levels().max(1);
        let chains = self.params.chains.max(1);
        let base = self.params.iters_per_temp.max(1);
        // Tempered chains run concurrently, so wall cost scales with the
        // ladder length only; per-unit cost already averages over chains.
        let per_iter = cost * (levels * chains) as f64;
        let raw = if per_iter > 0.0 {
            (window / per_iter) as usize
        } else {
            base * BUDGET_MAX_SCALE
        };
        Some((window, raw.clamp(BUDGET_MIN_ITERS, base * BUDGET_MAX_SCALE)))
    }

    /// Fold one measured replan into the EWMA cost model: wall ms per SA
    /// unit under the parameters the replan actually ran with.
    fn observe_replan_cost(&mut self, params: &SaParams, stats: &SearchStats) {
        if !self.adaptive_budget {
            return;
        }
        let units = (params.temp_levels().max(1)
            * params.iters_per_temp.max(1)
            * params.chains.max(1)) as f64;
        let measured = stats.overhead_ms / units;
        self.ewma_ms_per_unit = Some(match self.ewma_ms_per_unit {
            None => measured,
            Some(prev) => prev + BUDGET_EWMA_ALPHA * (measured - prev),
        });
    }

    /// Pack the jobs at `order[from..]` into trailing batches appended to
    /// `batches`: greedy up to `max_batch`, and — with a binding KV pool —
    /// never letting a seed batch's block occupancy exceed the pool (each
    /// job individually fits; [`WaveController::admit`] rejected the rest).
    /// With an unlimited pool this is the plain fixed-size chunking of the
    /// pre-KV controller, bit for bit. Shares [`kv::pack_greedy`] with the
    /// hard-mode repack fallback so the two packings cannot diverge.
    fn pack_tail(&self, order: &[usize], from: usize, batches: &mut Vec<usize>) {
        let pool = if self.params.kv.binding() {
            self.params.kv.pool_blocks
        } else {
            u64::MAX
        };
        kv::pack_greedy(
            order,
            from,
            self.table.kv_blocks_all(),
            self.params.max_batch,
            pool,
            batches,
        );
    }

    /// The warm seed for this admission: current plan order with the new
    /// jobs appended in admission order, packed into fresh trailing
    /// batches (KV-aware — [`WaveController::pack_tail`]).
    fn warm_seed(&self, old_n: usize) -> Schedule {
        let mut warm = self.plan.clone();
        let from = warm.order.len();
        warm.order.extend(old_n..self.jobs.len());
        self.pack_tail(&warm.order, from, &mut warm.batches);
        warm
    }

    /// The cold re-seed: frozen prefix as dispatched, then every
    /// undispatched job in admission order, packed into fresh batches
    /// (KV-aware — [`WaveController::pack_tail`]).
    fn cold_seed(&self, old_n: usize) -> Schedule {
        let frozen_pos = self.frozen_positions();
        let mut order: Vec<usize> = self.plan.order[..frozen_pos].to_vec();
        let mut in_prefix = vec![false; self.jobs.len()];
        for &j in &order {
            in_prefix[j] = true;
        }
        // previously admitted, undispatched jobs — then the new arrivals
        order.extend((0..old_n).filter(|&j| !in_prefix[j]));
        order.extend(old_n..self.jobs.len());
        let mut batches: Vec<usize> =
            self.plan.batches[..self.frozen_batches].to_vec();
        self.pack_tail(&order, frozen_pos, &mut batches);
        Schedule { order, batches }
    }

    /// Drop fully dispatched batches from the wave (see
    /// [`WaveController::with_compaction`]): fold their predicted end
    /// time into the timeline origin `t0`, drop their jobs and
    /// prediction-table rows, and remap the surviving plan onto the
    /// compacted indices.
    fn compact_dispatched(&mut self) {
        self.compact_dispatched_at(None);
    }

    /// [`WaveController::compact_dispatched`] with an optional **measured**
    /// timeline origin: `Some(now)` adopts the engine's actual free time
    /// as the new origin instead of the predicted prefix end (drift
    /// reconciliation — every subsequent predicted start then carries the
    /// observed drift), `None` keeps the predicted fold, bit for bit.
    fn compact_dispatched_at(&mut self, measured_t0: Option<f64>) {
        if self.frozen_batches == 0 {
            return;
        }
        let frozen_pos = self.frozen_positions();
        self.t0_ms = match measured_t0 {
            // Replay the dispatched batches on the timeline exactly as the
            // sequential evaluation would have (same order, same values —
            // including each batch's arrival max), so the suffix's
            // predicted entry waits are unchanged. With the arrival column
            // at zero this is the plain batch-maxima sum of the
            // pre-timeline controller.
            None => self.predicted_prefix_end(),
            Some(now) => now,
        };
        // compaction rewrites plan indices and the origin: restart the
        // incremental prefix-end fold from the new t0
        self.fold_k = 0;
        self.fold_pos = 0;
        self.fold_end = self.t0_ms;
        let n = self.jobs.len();
        let mut keep = vec![true; n];
        for &j in &self.plan.order[..frozen_pos] {
            keep[j] = false;
        }
        let mut remap = vec![usize::MAX; n];
        let mut w = 0usize;
        let mut jobs = Vec::with_capacity(n - frozen_pos);
        for (j, &k) in keep.iter().enumerate() {
            if k {
                remap[j] = w;
                jobs.push(self.jobs[j]);
                w += 1;
            }
        }
        self.jobs = jobs;
        self.table.compact(&keep);
        self.plan.order =
            self.plan.order[frozen_pos..].iter().map(|&j| remap[j]).collect();
        self.plan.batches.drain(..self.frozen_batches);
        self.retired_jobs += frozen_pos;
        self.frozen_batches = 0;
    }

    /// Admit newly arrived jobs and replan the undispatched suffix.
    ///
    /// Grows the job set and prediction table in place, then re-runs the
    /// SA search with the dispatched prefix frozen, seeded per the
    /// controller's [`ReplanStrategy`]. Returns the stats of this replan.
    ///
    /// The very first admission (nothing planned, nothing frozen) runs
    /// the plain closed-wave search — bit-identical to
    /// [`crate::coordinator::priority::annealing::priority_mapping`] over
    /// the same jobs and seed.
    ///
    /// # Errors
    /// With a binding KV pool, a job whose footprint alone exceeds the
    /// pool can never execute on this instance; admission fails with a
    /// descriptive error rather than planning a fiction.
    pub fn admit(&mut self, new_jobs: &[Job]) -> Result<SearchStats> {
        self.admit_impl(new_jobs, None)
    }

    /// [`WaveController::admit`] with per-job arrival times (ms): the
    /// arrival column feeds the timeline evaluation, so idle gaps before
    /// late arrivals and per-job arrival offsets shape every replanned
    /// entry wait (module docs). `arrivals.len()` must equal
    /// `new_jobs.len()`. With every arrival at 0.0 this is bit-identical
    /// to [`WaveController::admit`].
    ///
    /// # Errors
    /// Same oversize-job rule as [`WaveController::admit`].
    pub fn admit_at(
        &mut self,
        new_jobs: &[Job],
        arrivals: &[f64],
    ) -> Result<SearchStats> {
        assert_eq!(
            new_jobs.len(),
            arrivals.len(),
            "one arrival time per admitted job"
        );
        self.admit_impl(new_jobs, Some(arrivals))
    }

    fn admit_impl(
        &mut self,
        new_jobs: &[Job],
        arrivals: Option<&[f64]>,
    ) -> Result<SearchStats> {
        assert!(!new_jobs.is_empty(), "admit called with no jobs");
        let kv = self.params.kv;
        if kv.binding() {
            for job in new_jobs {
                let need = kv.job_blocks(job.input_len, job.output_len);
                if need > kv.pool_blocks {
                    bail!(
                        "request {} needs {need} KV blocks but the \
                         instance pool holds {} — it can never be batched \
                         on this instance",
                        job.req_idx,
                        kv.pool_blocks,
                    );
                }
            }
        }
        if self.compact {
            self.compact_dispatched();
        }
        let old_n = self.jobs.len();
        self.jobs.extend_from_slice(new_jobs);
        match arrivals {
            Some(a) => self.table.extend_at(new_jobs, self.predictor, a),
            None => self.table.extend(new_jobs, self.predictor),
        }

        let mut params = SaParams { seed: self.replan_seed(), ..self.params };
        let budget = self.adaptive_window();
        if let Some((_, iters)) = budget {
            params.iters_per_temp = iters;
        }
        let ev = Evaluator::with_arrivals(
            &self.jobs,
            self.predictor,
            self.t0_ms,
            self.table.arrivals_all(),
        )
        .with_chunk_tokens(self.params.chunk_tokens);
        let first_admission = old_n == 0 && self.frozen_batches == 0;
        let warm = if first_admission {
            // No live plan (first admission, or everything dispatched and
            // compacted): both strategies are the plain cold search.
            None
        } else {
            match self.strategy {
                ReplanStrategy::Warm => Some(self.warm_seed(old_n)),
                ReplanStrategy::Cold => Some(self.cold_seed(old_n)),
            }
        };
        // A cold restart without frozen work re-seeds from scratch.
        let warm = match (self.strategy, self.frozen_batches) {
            (ReplanStrategy::Cold, 0) => None,
            _ => warm,
        };
        let res = priority_mapping_warm(
            &ev,
            &self.table,
            &params,
            warm.as_ref(),
            self.frozen_batches,
        );
        debug_assert!(res.schedule.validate(params.max_batch.max(1)).is_ok());
        self.plan = res.schedule;
        self.eval = res.eval;
        self.stats.admitted += new_jobs.len();
        self.stats.replans += 1;
        self.stats.replan_ms_total += res.stats.overhead_ms;
        self.stats.replan_cpu_ms_total += res.stats.cpu_ms;
        self.stats.sa_evals += res.stats.evals;
        if let Some((window, _)) = budget {
            self.stats.budget_replans += 1;
            self.stats.budget_allotted_ms_total += window;
            self.stats.budget_spent_ms_total += res.stats.overhead_ms;
        }
        self.observe_replan_cost(&params, &res.stats);
        self.last_search = Some(res.stats);
        Ok(res.stats)
    }

    /// Execution-time maximum (ms) of one frozen batch under the active
    /// pricing: the whole-batch `exec_ms` max when chunking is off, the
    /// chunked per-member exec otherwise — mirroring the evaluators'
    /// chunk arithmetic operation for operation, so the prefix-end folds
    /// and the replanned suffix waits stay on one bit-identical timeline.
    fn frozen_batch_exec_max(&self, members: &[usize], bsize: usize) -> f64 {
        let mut bmax = 0.0f64;
        if self.table.chunk_tokens() == 0 {
            for &j in members {
                let e = self.table.get(j, bsize).exec_ms;
                if e > bmax {
                    bmax = e;
                }
            }
        } else {
            let mut chunk_total = 0.0f64;
            for &j in members {
                chunk_total += self.table.chunk_ms(j);
            }
            let mut offset = 0.0f64;
            for &j in members {
                offset += self.table.chunk_ms(j);
                let exec = if self.jobs[j].output_len <= 1 {
                    offset
                } else {
                    let p = self.table.get(j, bsize);
                    chunk_total + (p.exec_ms - p.prefill_ms)
                };
                if exec > bmax {
                    bmax = exec;
                }
            }
        }
        bmax
    }

    /// Predicted end time (ms) of the dispatched prefix on the wave
    /// timeline — what the engine clock *should* read once the prefix has
    /// executed, under the predictions the plan was priced with. Equals
    /// [`WaveController::t0_ms`] when nothing is frozen.
    pub fn predicted_prefix_end(&self) -> f64 {
        let mut free = self.t0_ms;
        let mut start = 0usize;
        for k in 0..self.frozen_batches {
            let bsize = self.plan.batches[k];
            let mut barr = f64::NEG_INFINITY;
            let members = &self.plan.order[start..start + bsize];
            for &j in members {
                let a = self.table.arrival_ms(j);
                if a > barr {
                    barr = a;
                }
            }
            let bmax = self.frozen_batch_exec_max(members, bsize);
            free = TimelineOrigin::batch_start(free, barr) + bmax;
            start += bsize;
        }
        free
    }

    /// Latest measured-minus-predicted prefix-end drift (ms); 0 until a
    /// [`WaveController::reconcile`] with dispatched work, and reset to 0
    /// by [`WaveController::replan_from_drift`].
    pub fn drift_ms(&self) -> f64 {
        self.drift_ms
    }

    /// Advance the incremental prefix-end fold over the batches frozen
    /// since the last call and return the predicted prefix end —
    /// bit-identical to [`WaveController::predicted_prefix_end`] at
    /// O(newly frozen batches) instead of O(prefix) per call (see the
    /// `fold_*` field docs).
    fn fold_prefix_end(&mut self) -> f64 {
        while self.fold_k < self.frozen_batches {
            let bsize = self.plan.batches[self.fold_k];
            let start = self.fold_pos;
            let mut barr = f64::NEG_INFINITY;
            let members = &self.plan.order[start..start + bsize];
            for &j in members {
                let a = self.table.arrival_ms(j);
                if a > barr {
                    barr = a;
                }
            }
            let bmax = self.frozen_batch_exec_max(members, bsize);
            self.fold_end =
                TimelineOrigin::batch_start(self.fold_end, barr) + bmax;
            self.fold_pos += bsize;
            self.fold_k += 1;
        }
        self.fold_end
    }

    /// Reconcile executed completions against the prediction timeline
    /// (module docs): record the signed drift between the engine's
    /// measured clock and [`WaveController::predicted_prefix_end`], plus
    /// per-request output-length divergence diagnostics from the batch's
    /// completions. Pure bookkeeping — no RNG, no plan mutation — so
    /// reconciling never perturbs a run. Returns the signed drift (ms);
    /// 0 when nothing is dispatched.
    pub fn reconcile(
        &mut self,
        completions: &[Completion],
        engine_now_ms: f64,
    ) -> f64 {
        for c in completions {
            self.stats.reconciled_jobs += 1;
            self.stats.lo_abs_divergence_sum +=
                c.lo_divergence().unsigned_abs() as f64;
        }
        if self.frozen_batches == 0 {
            return 0.0;
        }
        let predicted_end = self.fold_prefix_end();
        debug_assert_eq!(
            predicted_end.to_bits(),
            self.predicted_prefix_end().to_bits(),
            "incremental prefix-end fold diverged from the full fold"
        );
        let drift = engine_now_ms - predicted_end;
        self.drift_ms = drift;
        self.reconciled_now = Some(engine_now_ms);
        if drift.abs() > self.stats.max_abs_drift_ms {
            self.stats.max_abs_drift_ms = drift.abs();
        }
        drift
    }

    /// Shift the timeline origin to the measured engine time recorded by
    /// the last [`WaveController::reconcile`] and re-run the warm search
    /// over the undispatched suffix — the drift-reconciling replan behind
    /// [`OnlineOpts::replan_drift_ms`]. Implies prefix compaction: the
    /// dispatched work has been *measured*, so re-predicting it would
    /// re-introduce exactly the drift being corrected. Returns `None`
    /// when there is nothing to do (no reconciled measurement, nothing
    /// dispatched, or no live suffix — the origin still shifts in the
    /// last case).
    pub fn replan_from_drift(&mut self) -> Option<SearchStats> {
        let now = self.reconciled_now.take()?;
        if self.frozen_batches == 0 {
            return None;
        }
        self.compact_dispatched_at(Some(now));
        self.drift_ms = 0.0;
        if self.jobs.is_empty() {
            return None; // origin shifted; nothing live to replan
        }
        let mut params = SaParams { seed: self.replan_seed(), ..self.params };
        // A drift replan has just compacted the dispatched prefix away, so
        // the "next batch to dispatch" window is plan batch 0's predicted
        // execution — the adaptive sizing reads it the same way as an
        // admission replan.
        let budget = self.adaptive_window();
        if let Some((_, iters)) = budget {
            params.iters_per_temp = iters;
        }
        let warm = self.plan.clone();
        let ev = Evaluator::with_arrivals(
            &self.jobs,
            self.predictor,
            self.t0_ms,
            self.table.arrivals_all(),
        )
        .with_chunk_tokens(self.params.chunk_tokens);
        let res =
            priority_mapping_warm(&ev, &self.table, &params, Some(&warm), 0);
        debug_assert!(res.schedule.validate(params.max_batch.max(1)).is_ok());
        self.plan = res.schedule;
        self.eval = res.eval;
        self.stats.replans += 1;
        self.stats.drift_replans += 1;
        self.stats.replan_ms_total += res.stats.overhead_ms;
        self.stats.replan_cpu_ms_total += res.stats.cpu_ms;
        self.stats.sa_evals += res.stats.evals;
        if let Some((window, _)) = budget {
            self.stats.budget_replans += 1;
            self.stats.budget_allotted_ms_total += window;
            self.stats.budget_spent_ms_total += res.stats.overhead_ms;
        }
        self.observe_replan_cost(&params, &res.stats);
        self.last_search = Some(res.stats);
        Some(res.stats)
    }

    /// Pop the next undispatched batch, freezing it in place. Returns
    /// `None` when the whole plan has been dispatched.
    pub fn dispatch_next(&mut self) -> Option<Dispatch> {
        if self.drained() {
            return None;
        }
        let k = self.frozen_batches;
        let start: usize = self.plan.batches[..k].iter().sum();
        let size = self.plan.batches[k];
        let jobs: Vec<Job> = self.plan.order[start..start + size]
            .iter()
            .map(|&j| self.jobs[j])
            .collect();
        self.frozen_batches += 1;
        self.stats.dispatched_batches += 1;
        self.stats.dispatched_jobs += size;
        Some(Dispatch { batch: k, jobs })
    }
}

/// Predicted timeline of one request under the controller's final plan
/// (the objective-fidelity diagnostic: compare against the measured
/// [`Completion`] with the same id).
#[derive(Debug, Clone, Copy)]
pub struct PredictedJob {
    pub id: u64,
    /// Predicted waiting time (ms) — batch start minus arrival on the
    /// evaluation timeline.
    pub wait_ms: f64,
    /// Predicted e2e latency (ms) — wait plus predicted execution.
    pub e2e_ms: f64,
    /// Predicted time-to-first-token (ms) — wait plus the batch-wide
    /// prefill (whole-prompt mode) or this member's final prefill-chunk
    /// completion offset (chunked mode).
    pub ttft_ms: f64,
}

/// Outcome of one online serving run.
#[derive(Debug, Clone)]
pub struct OnlineOutcome {
    /// Measured completions, sorted by request id.
    pub completions: Vec<Completion>,
    pub stats: OnlineStats,
    /// Predicted evaluation of the final plan (diagnostics).
    pub final_eval: Eval,
    /// Per-request predicted waits/e2e under the final plan, sorted by
    /// request id. Covers every request when compaction is off; with
    /// compaction on, only the requests still tracked at the end of the
    /// trace. Join with `completions` to measure predicted-vs-executed
    /// error (`examples/online_serving.rs` reports it).
    pub predicted: Vec<PredictedJob>,
    /// Base SA seed of the run — with the trace seed, everything needed to
    /// reproduce the run exactly.
    pub seed: u64,
}

/// Tuning knobs for [`run_online_opts`]. The default reproduces
/// [`run_online`]'s historical behaviour exactly.
#[derive(Debug, Clone, Copy, Default)]
pub struct OnlineOpts {
    /// Compact fully dispatched batches out of the controller at each
    /// admission ([`WaveController::with_compaction`]): bounded memory on
    /// long traces, at the cost of the dispatched jobs' constant terms
    /// dropping out of the replanned objective.
    pub compact_dispatched: bool,
    /// Admit with real arrival times ([`WaveController::admit_at`]): the
    /// predicted objective evaluates on the arrival-aware timeline
    /// instead of the closed-wave t = 0 timeline. Off by default — the
    /// historical behaviour, bit for bit (and identical to on when every
    /// request arrives at t = 0).
    pub arrival_aware: bool,
    /// Drift-reconciling replan threshold (ms): after each dispatched
    /// batch executes, the controller reconciles the measured engine
    /// clock against the predicted prefix end, and when the |drift|
    /// reaches this threshold it shifts the timeline origin to the
    /// measured time and warm-replans the live suffix
    /// ([`WaveController::replan_from_drift`]). `0.0` (the default)
    /// disables drift replanning — the historical behaviour, bit for bit
    /// (reconciliation still records diagnostics; it never mutates the
    /// plan).
    pub replan_drift_ms: f64,
    /// Deadline-adaptive iteration budgets
    /// ([`WaveController::with_adaptive_budget`]): each replan's
    /// `iters_per_temp` is sized so its predicted wall time fits the
    /// predicted execution window of the next batch to dispatch. Off by
    /// default — the fixed-budget behaviour, bit for bit.
    pub adaptive_budget: bool,
    /// Fleet-level work stealing ([`run_online_fleet_migrating`]): a
    /// saturated instance sheds slack-ordered deferred work to a
    /// non-saturated peer's wave queue. Read only by the migrating fleet
    /// loop — the single-instance loops have no peer to steal from — and
    /// off by default: the independent per-instance behaviour, bit for
    /// bit.
    pub migrate: bool,
}

/// Event loop: drive one engine from a timestamped arrival stream (module
/// docs). `requests` must be sorted by `arrival_ms`; `predicted_out[i]`
/// is the output-length prediction for `requests[i]`.
///
/// Designed for virtual-clock engines ([`crate::engine::sim::SimEngine`]):
/// idle gaps jump via [`Engine::advance_to`]. Wall-clock engines (whose
/// `advance_to` is a no-op) are handled by sleeping until the next arrival.
pub fn run_online(
    requests: &[Request],
    predicted_out: &[usize],
    engine: &mut dyn Engine,
    predictor: &LatencyPredictor,
    params: &SaParams,
    strategy: ReplanStrategy,
) -> Result<OnlineOutcome> {
    run_online_opts(
        requests,
        predicted_out,
        engine,
        predictor,
        params,
        strategy,
        OnlineOpts::default(),
    )
}

/// [`run_online`] with explicit [`OnlineOpts`].
///
/// **KV deferral**: with a binding pool ([`SaParams::kv`]), arrivals are
/// deferred — not admitted — while the controller is
/// [`WaveController::saturated`] (a full pool's worth of planned work is
/// still undispatched). Deferred jobs are retried on the next loop
/// iteration, i.e. at the next replan opportunity after a dispatch has
/// drained backlog; with an unlimited pool nothing is ever deferred.
pub fn run_online_opts(
    requests: &[Request],
    predicted_out: &[usize],
    engine: &mut dyn Engine,
    predictor: &LatencyPredictor,
    params: &SaParams,
    strategy: ReplanStrategy,
    opts: OnlineOpts,
) -> Result<OnlineOutcome> {
    assert_eq!(requests.len(), predicted_out.len());
    // A NaN arrival would never satisfy the admission compare nor move
    // the virtual clock — the loop below would spin forever. Fail loudly.
    assert!(
        requests.iter().all(|r| r.arrival_ms.is_finite()),
        "arrival times must be finite"
    );
    debug_assert!(
        requests.windows(2).all(|w| w[0].arrival_ms <= w[1].arrival_ms),
        "arrival stream must be sorted by arrival_ms"
    );
    let mut ctl = WaveController::new(predictor, *params, strategy);
    if opts.compact_dispatched {
        ctl = ctl.with_compaction();
    }
    if opts.adaptive_budget {
        ctl = ctl.with_adaptive_budget();
    }
    let mut completions: Vec<Completion> = Vec::with_capacity(requests.len());
    let mut next = 0usize;
    let mut deferred: Vec<Job> = Vec::new();

    loop {
        // Admit everything that has arrived by the engine clock, starting
        // with jobs deferred while the KV backlog was saturated.
        let now = engine.now_ms();
        let mut fresh: Vec<Job> = std::mem::take(&mut deferred);
        let carried = fresh.len();
        while next < requests.len() && requests[next].arrival_ms <= now {
            fresh.push(Job::from_request(
                next,
                &requests[next],
                predicted_out[next],
            ));
            next += 1;
        }
        if !fresh.is_empty() {
            if ctl.saturated() {
                // Admission would overcommit the planned backlog: defer to
                // the next replan (after dispatching frees the pool).
                // Counting is per request id — a job can only ever count
                // one deferral, whatever cycles it goes through.
                for job in fresh.iter().skip(carried) {
                    ctl.note_deferral_of(requests[job.req_idx].id);
                }
                deferred = fresh;
            } else if opts.arrival_aware {
                let arrs: Vec<f64> = fresh
                    .iter()
                    .map(|job| requests[job.req_idx].arrival_ms)
                    .collect();
                ctl.admit_at(&fresh, &arrs)?;
            } else {
                ctl.admit(&fresh)?;
            }
        }
        // Dispatch the next planned batch (work-conserving: we never hold
        // a ready batch back to wait for better arrivals).
        if let Some(d) = ctl.dispatch_next() {
            let batch: Vec<EngineRequest> = d
                .jobs
                .iter()
                .map(|job| {
                    let r = &requests[job.req_idx];
                    EngineRequest {
                        id: r.id,
                        input_len: r.input_len,
                        max_new_tokens: r.output_len,
                        prompt: r.prompt.clone(),
                    }
                })
                .collect();
            // Absolute SLO deadlines feed the engine's slack-ordered
            // preemption victim selection; a no-op on engines without a
            // preemption model. The preemption counter is delta-tracked
            // around the dispatch so it stays distinct from deferrals.
            let deadlines: Vec<(u64, f64)> = d
                .jobs
                .iter()
                .map(|job| {
                    let r = &requests[job.req_idx];
                    (r.id, r.arrival_ms + slo_deadline_ms(&r.slo))
                })
                .collect();
            engine.set_deadlines(&deadlines);
            let pre = engine.preemption_stats().preemptions;
            let items = engine.run_batch(&batch)?;
            ctl.note_preemptions(
                engine.preemption_stats().preemptions.saturating_sub(pre),
            );
            let first_new = completions.len();
            for (job, item) in d.jobs.iter().zip(&items) {
                completions.push(super::to_completion(
                    &requests[job.req_idx],
                    item,
                    job.output_len,
                ));
            }
            // Reconcile the measured outcome against the prediction
            // timeline; a drift past the configured threshold triggers
            // the origin-shifting warm replan (module docs).
            let drift =
                ctl.reconcile(&completions[first_new..], engine.now_ms());
            if opts.replan_drift_ms > 0.0
                && drift.abs() >= opts.replan_drift_ms
            {
                ctl.replan_from_drift();
            }
            continue;
        }
        // Nothing dispatchable: deferred jobs go in at the next iteration
        // (the drained controller cannot be saturated), otherwise wait for
        // the next arrival or stop.
        if next >= requests.len() && deferred.is_empty() {
            break;
        }
        if !deferred.is_empty() {
            continue;
        }
        let arrival = requests[next].arrival_ms;
        engine.advance_to(arrival);
        if engine.now_ms() < arrival {
            // Wall-clock engine: let real time pass until the arrival.
            let wait = (arrival - engine.now_ms()).clamp(1.0, 50.0);
            std::thread::sleep(std::time::Duration::from_millis(wait as u64));
        }
    }

    completions.sort_by_key(|c| c.id);
    // Final-plan predicted timelines (objective-fidelity diagnostic):
    // evaluate the fully dispatched plan once on the controller's
    // timeline and key each job back to its request id.
    let mut predicted: Vec<PredictedJob> = {
        let ev = Evaluator::with_arrivals(
            ctl.jobs(),
            predictor,
            ctl.t0_ms(),
            ctl.arrivals(),
        )
        .with_chunk_tokens(params.chunk_tokens);
        let (_, timelines) = ev.eval_detailed(ctl.plan());
        timelines
            .iter()
            .map(|t| PredictedJob {
                id: requests[ctl.jobs()[t.job].req_idx].id,
                wait_ms: t.wait_ms,
                e2e_ms: t.wait_ms + t.exec_ms,
                ttft_ms: t.ttft_ms,
            })
            .collect()
    };
    predicted.sort_by_key(|p| p.id);
    Ok(OnlineOutcome {
        completions,
        stats: *ctl.stats(),
        final_eval: ctl.eval(),
        predicted,
        seed: params.seed,
    })
}

/// Fleet event loop: round-robin the arrival stream over `engines` (the
/// split a vLLM-style front-end applies) and run one [`WaveController`]
/// per instance at its [`instance_seed`]. Instance virtual clocks are
/// independent, so the per-instance loops compose exactly.
///
/// Returns merged completions (sorted by id) plus per-instance outcomes.
pub fn run_online_fleet(
    requests: &[Request],
    predicted_out: &[usize],
    engines: &mut [Box<dyn Engine + Send>],
    predictor: &LatencyPredictor,
    params: &SaParams,
    strategy: ReplanStrategy,
) -> Result<(Vec<Completion>, Vec<OnlineOutcome>)> {
    run_online_fleet_opts(
        requests,
        predicted_out,
        engines,
        predictor,
        params,
        strategy,
        OnlineOpts::default(),
    )
}

/// [`run_online_fleet`] with explicit [`OnlineOpts`] applied to every
/// per-instance event loop.
pub fn run_online_fleet_opts(
    requests: &[Request],
    predicted_out: &[usize],
    engines: &mut [Box<dyn Engine + Send>],
    predictor: &LatencyPredictor,
    params: &SaParams,
    strategy: ReplanStrategy,
    opts: OnlineOpts,
) -> Result<(Vec<Completion>, Vec<OnlineOutcome>)> {
    assert_eq!(requests.len(), predicted_out.len());
    assert!(!engines.is_empty());
    let n_inst = engines.len();
    let mut per_req: Vec<Vec<Request>> = vec![Vec::new(); n_inst];
    let mut per_out: Vec<Vec<usize>> = vec![Vec::new(); n_inst];
    for (i, r) in requests.iter().enumerate() {
        per_req[i % n_inst].push(r.clone());
        per_out[i % n_inst].push(predicted_out[i]);
    }
    let mut outcomes = Vec::with_capacity(n_inst);
    let mut completions = Vec::with_capacity(requests.len());
    for (inst, engine) in engines.iter_mut().enumerate() {
        let p = SaParams { seed: instance_seed(params.seed, inst), ..*params };
        let outcome = run_online_opts(
            &per_req[inst],
            &per_out[inst],
            engine.as_mut(),
            predictor,
            &p,
            strategy,
            opts,
        )?;
        completions.extend_from_slice(&outcome.completions);
        outcomes.push(outcome);
    }
    completions.sort_by_key(|c| c.id);
    Ok((completions, outcomes))
}

/// [`run_online_fleet_opts`] with **cross-instance migration**: the
/// per-instance event loops are interleaved round-robin in one global
/// loop, and between rounds a saturated instance sheds its deferred work
/// to a non-saturated peer's wave queue (work stealing between the
/// per-instance admission queues).
///
/// Mechanics per migration round, all deterministic:
///
/// * only sources that are [`WaveController::saturated`] **and** holding
///   deferred arrivals shed work — a deferred request is stuck behind a
///   full pool's worth of planned backlog, which is exactly the state
///   migration exists to drain;
/// * a source considers its deferred requests most-urgent-first
///   (ascending [`slack_key`] against the source clock, ties by request
///   index), so the work that can least afford the wait moves first;
/// * the target is the non-saturated peer with the smallest undispatched
///   backlog that has block headroom for the request (ties to the lowest
///   instance index); requests no peer can host stay deferred at the
///   source — residual overcommit is the engine preemption layer's
///   problem, not silently dropped;
/// * migrations are counted on the shedding instance
///   ([`OnlineStats::migrations`]), and the fleet-level deferral dedup
///   spans instances, so a request bounced across queues still counts
///   one deferral.
///
/// With `opts.migrate == false` — or a single-instance fleet, which has
/// no peer — no migration is ever attempted, and because per-instance
/// state is otherwise independent, the interleaved loop replays
/// [`run_online_fleet_opts`] bit for bit.
pub fn run_online_fleet_migrating(
    requests: &[Request],
    predicted_out: &[usize],
    engines: &mut [Box<dyn Engine + Send>],
    predictor: &LatencyPredictor,
    params: &SaParams,
    strategy: ReplanStrategy,
    opts: OnlineOpts,
) -> Result<(Vec<Completion>, Vec<OnlineOutcome>)> {
    assert_eq!(requests.len(), predicted_out.len());
    assert!(!engines.is_empty());
    assert!(
        requests.iter().all(|r| r.arrival_ms.is_finite()),
        "arrival times must be finite"
    );
    debug_assert!(
        requests.windows(2).all(|w| w[0].arrival_ms <= w[1].arrival_ms),
        "arrival stream must be sorted by arrival_ms"
    );
    let n_inst = engines.len();
    // Round-robin assignment of *global* request indices — the same split
    // run_online_fleet applies. Jobs keep their global req_idx, so a
    // migrated request needs no re-indexing at the target.
    let mut pending: Vec<VecDeque<usize>> = vec![VecDeque::new(); n_inst];
    for g in 0..requests.len() {
        pending[g % n_inst].push_back(g);
    }
    let mut ctls: Vec<WaveController> = (0..n_inst)
        .map(|inst| {
            let p =
                SaParams { seed: instance_seed(params.seed, inst), ..*params };
            let mut c = WaveController::new(predictor, p, strategy);
            if opts.compact_dispatched {
                c = c.with_compaction();
            }
            if opts.adaptive_budget {
                c = c.with_adaptive_budget();
            }
            c
        })
        .collect();
    let mut deferred: Vec<Vec<usize>> = vec![Vec::new(); n_inst];
    let mut completed: Vec<Vec<Completion>> = vec![Vec::new(); n_inst];
    // Fleet-level first-deferral dedup: a request bounced between
    // instances by migration must still count exactly one deferral.
    let mut deferral_counted: HashSet<u64> = HashSet::new();

    loop {
        let mut progressed = false;
        // Phase 1 — admission: deferred work first (it arrived long ago),
        // then everything that has arrived by each instance's clock.
        // Per instance and per round this is exactly run_online's
        // admit-then-dispatch sequence; the phases only batch the steps
        // across instances so migration can observe every queue in its
        // post-admission (saturated-or-not) state, *before* a dispatch
        // drains the backlog the deferral was measured against.
        for i in 0..n_inst {
            let now = engines[i].now_ms();
            let carried: Vec<usize> = std::mem::take(&mut deferred[i]);
            let carried_n = carried.len();
            let mut fresh: Vec<Job> = carried
                .iter()
                .map(|&g| Job::from_request(g, &requests[g], predicted_out[g]))
                .collect();
            while let Some(&g) = pending[i].front() {
                if requests[g].arrival_ms > now {
                    break;
                }
                pending[i].pop_front();
                fresh.push(Job::from_request(g, &requests[g], predicted_out[g]));
            }
            if !fresh.is_empty() {
                if ctls[i].saturated() {
                    for job in fresh.iter().skip(carried_n) {
                        if deferral_counted.insert(requests[job.req_idx].id) {
                            ctls[i].note_deferrals(1);
                        }
                    }
                    deferred[i] = fresh.iter().map(|j| j.req_idx).collect();
                } else if opts.arrival_aware {
                    let arrs: Vec<f64> = fresh
                        .iter()
                        .map(|job| requests[job.req_idx].arrival_ms)
                        .collect();
                    ctls[i].admit_at(&fresh, &arrs)?;
                } else {
                    ctls[i].admit(&fresh)?;
                }
            }
        }

        // Phase 2 — migration: saturated sources shed deferred work to
        // non-saturated peers (rules in the function docs). Runs between
        // admission and dispatch so sources are seen in the saturated
        // state that caused the deferral.
        if opts.migrate && n_inst > 1 {
            for src in 0..n_inst {
                if deferred[src].is_empty() || !ctls[src].saturated() {
                    continue;
                }
                let now = engines[src].now_ms();
                // Most urgent first: least relative slack on the queue the
                // request is actually stuck in.
                deferred[src].sort_by(|&a, &b| {
                    let key = |g: usize| {
                        let r = &requests[g];
                        let exec = predictor
                            .predict(1, r.input_len, predicted_out[g])
                            .exec_ms;
                        slack_key(
                            r.arrival_ms + slo_deadline_ms(&r.slo) - now,
                            exec,
                        )
                    };
                    key(a).total_cmp(&key(b)).then(a.cmp(&b))
                });
                let mut kept: Vec<usize> = Vec::new();
                for g in std::mem::take(&mut deferred[src]) {
                    let need = params
                        .kv
                        .job_blocks(requests[g].input_len, predicted_out[g]);
                    let mut tgt: Option<(u64, usize)> = None;
                    for j in 0..n_inst {
                        if j == src || ctls[j].saturated() {
                            continue;
                        }
                        let undis = ctls[j].undispatched_blocks();
                        let headroom =
                            params.kv.pool_blocks.saturating_sub(undis);
                        if params.kv.binding() && headroom < need {
                            continue;
                        }
                        let better = match tgt {
                            None => true,
                            Some((u, _)) => undis < u,
                        };
                        if better {
                            tgt = Some((undis, j));
                        }
                    }
                    match tgt {
                        Some((_, j)) => {
                            // Into the peer's admission queue: it is not
                            // saturated, so the next round admits it.
                            deferred[j].push(g);
                            ctls[src].note_migrations(1);
                        }
                        None => kept.push(g),
                    }
                }
                deferred[src] = kept;
            }
        }

        // Phase 3 — dispatch one planned batch per instance, exactly as
        // run_online would.
        for i in 0..n_inst {
            if let Some(d) = ctls[i].dispatch_next() {
                let batch: Vec<EngineRequest> = d
                    .jobs
                    .iter()
                    .map(|job| {
                        let r = &requests[job.req_idx];
                        EngineRequest {
                            id: r.id,
                            input_len: r.input_len,
                            max_new_tokens: r.output_len,
                            prompt: r.prompt.clone(),
                        }
                    })
                    .collect();
                let deadlines: Vec<(u64, f64)> = d
                    .jobs
                    .iter()
                    .map(|job| {
                        let r = &requests[job.req_idx];
                        (r.id, r.arrival_ms + slo_deadline_ms(&r.slo))
                    })
                    .collect();
                engines[i].set_deadlines(&deadlines);
                let pre = engines[i].preemption_stats().preemptions;
                let items = engines[i].run_batch(&batch)?;
                ctls[i].note_preemptions(
                    engines[i]
                        .preemption_stats()
                        .preemptions
                        .saturating_sub(pre),
                );
                let first_new = completed[i].len();
                for (job, item) in d.jobs.iter().zip(&items) {
                    completed[i].push(super::to_completion(
                        &requests[job.req_idx],
                        item,
                        job.output_len,
                    ));
                }
                let drift = ctls[i]
                    .reconcile(&completed[i][first_new..], engines[i].now_ms());
                if opts.replan_drift_ms > 0.0
                    && drift.abs() >= opts.replan_drift_ms
                {
                    ctls[i].replan_from_drift();
                }
                progressed = true;
            }
        }

        let done = (0..n_inst).all(|i| {
            ctls[i].drained()
                && pending[i].is_empty()
                && deferred[i].is_empty()
        });
        if done {
            break;
        }
        if progressed {
            continue;
        }
        // Nothing dispatched anywhere, so every controller is drained. A
        // deferred job is admitted next round (a drained controller is
        // never saturated); otherwise jump each idle instance's virtual
        // clock to its next arrival.
        if (0..n_inst).any(|i| !deferred[i].is_empty()) {
            continue;
        }
        let mut moved = false;
        for i in 0..n_inst {
            if let Some(&g) = pending[i].front() {
                let arrival = requests[g].arrival_ms;
                engines[i].advance_to(arrival);
                if engines[i].now_ms() >= arrival {
                    moved = true;
                }
            }
        }
        if !moved {
            // Wall-clock engines: let real time pass (mirrors run_online).
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
    }

    let mut merged: Vec<Completion> = Vec::with_capacity(requests.len());
    let mut outcomes: Vec<OnlineOutcome> = Vec::with_capacity(n_inst);
    for (inst, ctl) in ctls.iter().enumerate() {
        let mut completions = std::mem::take(&mut completed[inst]);
        completions.sort_by_key(|c| c.id);
        let mut predicted: Vec<PredictedJob> = {
            let ev = Evaluator::with_arrivals(
                ctl.jobs(),
                predictor,
                ctl.t0_ms(),
                ctl.arrivals(),
            )
            .with_chunk_tokens(params.chunk_tokens);
            let (_, timelines) = ev.eval_detailed(ctl.plan());
            timelines
                .iter()
                .map(|t| PredictedJob {
                    id: requests[ctl.jobs()[t.job].req_idx].id,
                    wait_ms: t.wait_ms,
                    e2e_ms: t.wait_ms + t.exec_ms,
                    ttft_ms: t.ttft_ms,
                })
                .collect()
        };
        predicted.sort_by_key(|p| p.id);
        merged.extend_from_slice(&completions);
        outcomes.push(OnlineOutcome {
            completions,
            stats: *ctl.stats(),
            final_eval: ctl.eval(),
            predicted,
            seed: instance_seed(params.seed, inst),
        });
    }
    merged.sort_by_key(|c| c.id);
    Ok((merged, outcomes))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::profiles::by_name;
    use crate::coordinator::priority::annealing::priority_mapping;
    use crate::coordinator::request::{Slo, TaskType};
    use crate::engine::sim::SimEngine;
    use crate::util::rng::Rng;

    fn predictor() -> LatencyPredictor {
        LatencyPredictor::paper_table2()
    }

    fn job(i: usize, rng: &mut Rng) -> Job {
        Job {
            req_idx: i,
            input_len: 1 + rng.below(1200),
            output_len: 1 + rng.below(300),
            slo: Slo::E2e { e2e_ms: rng.uniform(1_000.0, 20_000.0) },
        }
    }

    fn params(max_batch: usize, seed: u64) -> SaParams {
        SaParams {
            max_batch,
            seed,
            t0: 100.0,
            iters_per_temp: 20,
            ..Default::default()
        }
    }

    #[test]
    fn single_admission_matches_priority_mapping() {
        let pred = predictor();
        let mut rng = Rng::new(3);
        let jobs: Vec<Job> = (0..14).map(|i| job(i, &mut rng)).collect();
        let p = params(4, 9);
        let mut ctl = WaveController::new(&pred, p, ReplanStrategy::Warm);
        ctl.admit(&jobs).unwrap();
        let ev = Evaluator::new(&jobs, &pred);
        let offline = priority_mapping(&ev, &p);
        assert_eq!(ctl.plan(), &offline.schedule);
        assert_eq!(ctl.eval(), offline.eval);
    }

    #[test]
    fn dispatch_freezes_batches_in_plan_order() {
        let pred = predictor();
        let mut rng = Rng::new(4);
        let jobs: Vec<Job> = (0..10).map(|i| job(i, &mut rng)).collect();
        let mut ctl =
            WaveController::new(&pred, params(3, 1), ReplanStrategy::Warm);
        ctl.admit(&jobs).unwrap();
        let plan = ctl.plan().clone();
        let mut seen = Vec::new();
        let mut k = 0;
        while let Some(d) = ctl.dispatch_next() {
            assert_eq!(d.batch, k);
            assert_eq!(d.jobs.len(), plan.batches[k]);
            seen.extend(d.jobs.iter().map(|j| j.req_idx));
            k += 1;
        }
        assert!(ctl.drained());
        let planned: Vec<usize> =
            plan.order.iter().map(|&j| jobs[j].req_idx).collect();
        assert_eq!(seen, planned);
    }

    #[test]
    fn replanning_after_dispatch_respects_frozen_prefix_and_warm_seed() {
        let pred = predictor();
        let mut rng = Rng::new(5);
        let first: Vec<Job> = (0..8).map(|i| job(i, &mut rng)).collect();
        for strategy in [ReplanStrategy::Warm, ReplanStrategy::Cold] {
            let mut ctl =
                WaveController::new(&pred, params(3, 2), strategy);
            ctl.admit(&first).unwrap();
            let d = ctl.dispatch_next().unwrap();
            let dispatched: Vec<usize> =
                d.jobs.iter().map(|j| j.req_idx).collect();
            let second: Vec<Job> =
                (8..13).map(|i| job(i, &mut rng)).collect();
            ctl.admit(&second).unwrap();
            ctl.plan().validate(3).unwrap();
            assert_eq!(ctl.plan().len(), 13);
            // dispatched batch unchanged at the head of the new plan
            let fp = ctl.frozen_positions();
            assert_eq!(fp, dispatched.len());
            let head: Vec<usize> = ctl.plan().order[..fp]
                .iter()
                .map(|&j| ctl.jobs()[j].req_idx)
                .collect();
            assert_eq!(head, dispatched, "{strategy:?}");
        }
    }

    #[test]
    fn warm_replan_never_ends_below_its_warm_seed() {
        let pred = predictor();
        let mut rng = Rng::new(6);
        let mut ctl =
            WaveController::new(&pred, params(4, 3), ReplanStrategy::Warm);
        let mut admitted = 0usize;
        for round in 0..4 {
            let fresh: Vec<Job> = (admitted..admitted + 4 + round)
                .map(|i| job(i, &mut rng))
                .collect();
            let old_n = admitted;
            admitted += fresh.len();
            // reconstruct the warm seed the controller will use
            let warm_eval = if old_n == 0 {
                None
            } else {
                let mut all: Vec<Job> = ctl.jobs().to_vec();
                all.extend_from_slice(&fresh);
                let warm = {
                    let mut w = ctl.plan().clone();
                    w.order.extend(old_n..admitted);
                    let mut left = fresh.len();
                    while left > 0 {
                        let b = left.min(4);
                        w.batches.push(b);
                        left -= b;
                    }
                    w
                };
                Some(Evaluator::new(&all, &pred).eval(&warm))
            };
            ctl.admit(&fresh).unwrap();
            if let Some(seed_eval) = warm_eval {
                assert!(
                    ctl.eval().g >= seed_eval.g,
                    "round {round}: replan {:?} below warm seed {:?}",
                    ctl.eval(),
                    seed_eval
                );
            }
            ctl.dispatch_next();
        }
    }

    #[test]
    fn adaptive_budget_first_replan_runs_at_the_configured_budget() {
        // No cost measurement exists before the first replan, so the
        // adaptive controller must replay the fixed-budget controller bit
        // for bit on it.
        let pred = predictor();
        let mut rng = Rng::new(21);
        let jobs: Vec<Job> = (0..12).map(|i| job(i, &mut rng)).collect();
        let p = params(4, 17);
        let mut fixed = WaveController::new(&pred, p, ReplanStrategy::Warm);
        let mut adaptive = WaveController::new(&pred, p, ReplanStrategy::Warm)
            .with_adaptive_budget();
        let sf = fixed.admit(&jobs).unwrap();
        let sa = adaptive.admit(&jobs).unwrap();
        assert_eq!(fixed.plan(), adaptive.plan());
        assert_eq!(fixed.eval(), adaptive.eval());
        assert_eq!(sf.evals, sa.evals);
        assert_eq!(adaptive.stats().budget_replans, 0);
        assert_eq!(adaptive.stats().budget_allotted_ms_total, 0.0);
    }

    #[test]
    fn adaptive_budget_sizes_later_replans_and_records_utilization() {
        let pred = predictor();
        let mut rng = Rng::new(22);
        let first: Vec<Job> = (0..10).map(|i| job(i, &mut rng)).collect();
        let mut ctl = WaveController::new(&pred, params(3, 5), ReplanStrategy::Warm)
            .with_adaptive_budget();
        ctl.admit(&first).unwrap();
        // first replan measured a cost and a next batch is planned: the
        // second replan runs under a budget window
        let second: Vec<Job> = (10..16).map(|i| job(i, &mut rng)).collect();
        let stats = ctl.admit(&second).unwrap();
        assert_eq!(ctl.stats().budget_replans, 1);
        assert!(ctl.stats().budget_allotted_ms_total > 0.0);
        assert!(ctl.stats().budget_spent_ms_total >= 0.0);
        assert!(ctl.stats().budget_utilization() >= 0.0);
        // the budgeted search still did real work within the clamp
        assert!(stats.evals > 0);
        ctl.plan().validate(3).unwrap();
        assert_eq!(ctl.plan().len(), 16);
        // wall and cpu accounting agree at chains == 1
        let s = ctl.stats();
        assert!((s.replan_cpu_ms_total - s.replan_ms_total).abs() < 1e-9);
    }

    #[test]
    fn run_online_serves_every_request_and_replans() {
        let mut profile = by_name("qwen7b-v100x2-vllm").unwrap();
        profile.noise_std = 0.0;
        let pred = profile.truth;
        let mut engine = SimEngine::new(profile, 4, 0);
        let mut reqs: Vec<Request> = (0..16)
            .map(|i| {
                Request::synthetic(
                    i as u64,
                    TaskType::Code,
                    100 + 40 * i as usize,
                    10 + 5 * i as usize,
                    Slo::E2e { e2e_ms: 60_000.0 },
                )
            })
            .collect();
        for (i, r) in reqs.iter_mut().enumerate() {
            r.arrival_ms = 400.0 * (i / 4) as f64; // 4 waves of 4
        }
        let outs: Vec<usize> = reqs.iter().map(|r| r.output_len).collect();
        let out = run_online(
            &reqs,
            &outs,
            &mut engine,
            &pred,
            &params(4, 11),
            ReplanStrategy::Warm,
        )
        .unwrap();
        assert_eq!(out.completions.len(), 16);
        for (i, c) in out.completions.iter().enumerate() {
            assert_eq!(c.id, i as u64);
            assert!(c.wait_ms >= -1e-9, "negative wait: {c:?}");
            assert!(c.e2e_ms > 0.0);
        }
        assert!(out.stats.replans >= 2, "{:?}", out.stats);
        assert_eq!(out.stats.admitted, 16);
        assert_eq!(out.stats.dispatched_jobs, 16);
        assert_eq!(out.seed, 11);
    }

    #[test]
    fn run_online_is_deterministic_per_seed() {
        let run = |seed: u64| {
            let mut profile = by_name("qwen7b-v100x2-vllm").unwrap();
            profile.noise_std = 0.0;
            let pred = profile.truth;
            let mut engine = SimEngine::new(profile, 2, seed);
            let mut reqs: Vec<Request> = (0..10)
                .map(|i| {
                    Request::synthetic(
                        i as u64,
                        TaskType::Code,
                        150 + 30 * i as usize,
                        12,
                        Slo::E2e { e2e_ms: 30_000.0 },
                    )
                })
                .collect();
            for (i, r) in reqs.iter_mut().enumerate() {
                r.arrival_ms = 250.0 * (i / 2) as f64;
            }
            let outs: Vec<usize> =
                reqs.iter().map(|r| r.output_len).collect();
            let out = run_online(
                &reqs,
                &outs,
                &mut engine,
                &pred,
                &params(2, seed),
                ReplanStrategy::Warm,
            )
            .unwrap();
            out.completions
                .iter()
                .map(|c| (c.id, c.e2e_ms.to_bits()))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(7), run(7));
    }

    #[test]
    fn fleet_round_robin_covers_all_requests() {
        let mut profile = by_name("qwen7b-v100x2-vllm").unwrap();
        profile.noise_std = 0.0;
        let pred = profile.truth;
        let mut engines: Vec<Box<dyn Engine + Send>> = (0..3)
            .map(|i| {
                Box::new(SimEngine::new(profile.clone(), 2, i as u64))
                    as Box<dyn Engine + Send>
            })
            .collect();
        let reqs: Vec<Request> = (0..12)
            .map(|i| {
                let mut r = Request::synthetic(
                    i as u64,
                    TaskType::Code,
                    100 + 20 * i as usize,
                    8,
                    Slo::E2e { e2e_ms: 60_000.0 },
                );
                r.arrival_ms = 100.0 * i as f64;
                r
            })
            .collect();
        let outs: Vec<usize> = reqs.iter().map(|r| r.output_len).collect();
        let (completions, outcomes) = run_online_fleet(
            &reqs,
            &outs,
            &mut engines,
            &pred,
            &params(2, 5),
            ReplanStrategy::Warm,
        )
        .unwrap();
        assert_eq!(completions.len(), 12);
        assert!(completions.windows(2).all(|w| w[0].id < w[1].id));
        assert_eq!(outcomes.len(), 3);
        let total: usize = outcomes.iter().map(|o| o.stats.admitted).sum();
        assert_eq!(total, 12);
        // per-instance seeds are derived, not shared
        assert_eq!(outcomes[0].seed, instance_seed(5, 0));
        assert_eq!(outcomes[1].seed, instance_seed(5, 1));
    }

    #[test]
    fn compaction_bounds_wave_size_on_long_traces() {
        // ROADMAP follow-up: the job set / prediction table must not grow
        // unboundedly on long traces. 60 waves of 4 jobs each, fully
        // dispatched between admissions: a compacting controller stays at
        // one wave's worth of live jobs; the legacy one keeps them all.
        let pred = predictor();
        let mut rng = Rng::new(17);
        let mut compacting =
            WaveController::new(&pred, params(2, 3), ReplanStrategy::Warm)
                .with_compaction();
        let mut legacy =
            WaveController::new(&pred, params(2, 3), ReplanStrategy::Warm);
        let mut dispatched: Vec<usize> = Vec::new();
        let mut admitted = 0usize;
        for wave in 0..60 {
            let fresh: Vec<Job> =
                (admitted..admitted + 4).map(|i| job(i, &mut rng)).collect();
            admitted += 4;
            compacting.admit(&fresh).unwrap();
            legacy.admit(&fresh).unwrap();
            assert!(
                compacting.jobs().len() <= 4,
                "wave {wave}: compacted controller holds {} jobs",
                compacting.jobs().len()
            );
            assert_eq!(legacy.jobs().len(), admitted);
            while let Some(d) = compacting.dispatch_next() {
                dispatched.extend(d.jobs.iter().map(|j| j.req_idx));
            }
            while legacy.dispatch_next().is_some() {}
            // suffix entry waits survive compaction as the base offset
            assert!(compacting.base_wait_ms() > 0.0 || wave == 0);
        }
        assert_eq!(compacting.retired_jobs(), admitted - 4);
        // every admitted job was dispatched exactly once, in req_idx terms
        let mut sorted = dispatched.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..admitted).collect::<Vec<_>>());
    }

    #[test]
    fn compaction_preserves_suffix_entry_wait() {
        // One admission, fully dispatched, then a second admission: the
        // compacted controller's base wait must equal the dispatched
        // batches' predicted maxima — the wait the suffix would have seen
        // without compaction.
        let pred = predictor();
        let mut rng = Rng::new(23);
        let first: Vec<Job> = (0..6).map(|i| job(i, &mut rng)).collect();
        let mut ctl =
            WaveController::new(&pred, params(3, 9), ReplanStrategy::Warm)
                .with_compaction();
        ctl.admit(&first).unwrap();
        let plan = ctl.plan().clone();
        let mut expected_wait = 0.0f64;
        for (_, start, size) in plan.batch_spans() {
            let bmax = plan.order[start..start + size]
                .iter()
                .map(|&j| pred.predict(size, first[j].input_len, first[j].output_len).exec_ms)
                .fold(0.0f64, f64::max);
            expected_wait += bmax;
        }
        while ctl.dispatch_next().is_some() {}
        let second: Vec<Job> = (6..9).map(|i| job(i, &mut rng)).collect();
        ctl.admit(&second).unwrap();
        assert_eq!(ctl.jobs().len(), 3);
        assert!(
            (ctl.base_wait_ms() - expected_wait).abs() < 1e-9,
            "base wait {} != dispatched prefix wait {expected_wait}",
            ctl.base_wait_ms()
        );
        ctl.plan().validate(3).unwrap();
    }

    #[test]
    fn admit_at_zero_arrivals_is_bit_identical_to_admit() {
        let pred = predictor();
        let mut rng = Rng::new(31);
        let jobs: Vec<Job> = (0..12).map(|i| job(i, &mut rng)).collect();
        let p = params(3, 8);
        let mut legacy = WaveController::new(&pred, p, ReplanStrategy::Warm);
        let mut timeline = WaveController::new(&pred, p, ReplanStrategy::Warm);
        legacy.admit(&jobs[..7]).unwrap();
        timeline.admit_at(&jobs[..7], &[0.0; 7]).unwrap();
        assert_eq!(legacy.plan(), timeline.plan());
        assert_eq!(legacy.eval().g.to_bits(), timeline.eval().g.to_bits());
        legacy.dispatch_next().unwrap();
        timeline.dispatch_next().unwrap();
        legacy.admit(&jobs[7..]).unwrap();
        timeline.admit_at(&jobs[7..], &[0.0; 5]).unwrap();
        assert_eq!(legacy.plan(), timeline.plan());
        assert_eq!(
            legacy.eval().total_e2e_ms.to_bits(),
            timeline.eval().total_e2e_ms.to_bits()
        );
    }

    #[test]
    fn arrival_aware_admission_measures_waits_from_arrival() {
        // Two jobs arriving 10 s apart: on the arrival-aware timeline the
        // second job's predicted wait is ~0 (the engine idles until it
        // arrives), while the t = 0 timeline charges it the full gap.
        let pred = predictor();
        let p = params(1, 4);
        let jobs: Vec<Job> = (0..2)
            .map(|i| Job {
                req_idx: i,
                input_len: 200,
                output_len: 20,
                slo: Slo::E2e { e2e_ms: 1e9 },
            })
            .collect();
        let arrivals = [0.0, 10_000.0];
        let mut ctl = WaveController::new(&pred, p, ReplanStrategy::Warm);
        ctl.admit_at(&jobs, &arrivals).unwrap();
        let ev = Evaluator::with_arrivals(
            ctl.jobs(),
            &pred,
            ctl.t0_ms(),
            ctl.arrivals(),
        );
        let (_, tl) = ev.eval_detailed(ctl.plan());
        // singleton batches; find the timeline row of plan job 1
        let late = tl.iter().find(|t| t.job == 1).unwrap();
        assert_eq!(late.start_ms, 10_000.0, "idle gap not modeled");
        assert_eq!(late.wait_ms, 0.0, "wait not measured from arrival");
        // compaction folds the dispatched prefix's *timeline* end into t0
        let mut ctl2 = WaveController::new(&pred, p, ReplanStrategy::Warm)
            .with_compaction();
        ctl2.admit_at(&jobs[..1], &arrivals[..1]).unwrap();
        while ctl2.dispatch_next().is_some() {}
        ctl2.admit_at(&jobs[1..], &arrivals[1..]).unwrap();
        let exec0 = pred.predict(1, 200, 20).exec_ms;
        assert!(
            (ctl2.t0_ms() - exec0).abs() < 1e-9,
            "t0 {} != dispatched prefix end {exec0}",
            ctl2.t0_ms()
        );
    }

    #[test]
    fn phased_backlog_saturates_later_than_reserve() {
        use crate::coordinator::kv::{KvConfig, KvPhaseModel};
        let pred = predictor();
        // job 0: 160 in / 4 out (11 blocks full); job 1: 160 in / 160 out
        // (20 blocks). Loose SLOs + a 31-block pool: the sorted seed [2]
        // meets every SLO and fits, so both controllers early-exit with
        // the same single-batch plan — deterministically.
        let mk = |i: usize, out: usize| Job {
            req_idx: i,
            input_len: 160,
            output_len: out,
            slo: Slo::E2e { e2e_ms: 1e9 },
        };
        let jobs = vec![mk(0, 4), mk(1, 160)];
        let kv = KvConfig::hard(31);
        let p_res = SaParams { kv, ..params(2, 3) };
        let p_pha = SaParams {
            kv: kv.with_phase(KvPhaseModel::Phased),
            ..params(2, 3)
        };
        let mut res = WaveController::new(&pred, p_res, ReplanStrategy::Warm);
        let mut pha = WaveController::new(&pred, p_pha, ReplanStrategy::Warm);
        res.admit(&jobs).unwrap();
        pha.admit(&jobs).unwrap();
        assert_eq!(res.plan().batches, vec![2]);
        assert_eq!(pha.plan().batches, vec![2]);
        // reserve charges the batch its footprint sum: 11 + 20 = 31 >= 31
        assert_eq!(res.undispatched_blocks(), 31);
        assert!(res.saturated());
        // phased charges the true occupancy peak: both alive at g = 4 is
        // 2 x 11 = 22 blocks — the backlog does not saturate the pool
        assert_eq!(pha.undispatched_blocks(), 22);
        assert!(!pha.saturated());
    }

    #[test]
    fn reconcile_measures_prefix_drift_and_replan_shifts_origin() {
        let pred = predictor();
        let mut rng = Rng::new(41);
        let jobs: Vec<Job> = (0..9).map(|i| job(i, &mut rng)).collect();
        let mut ctl =
            WaveController::new(&pred, params(3, 6), ReplanStrategy::Warm);
        ctl.admit(&jobs).unwrap();
        // nothing dispatched: reconcile is a no-op returning zero drift
        assert_eq!(ctl.reconcile(&[], 123.0), 0.0);
        assert_eq!(ctl.drift_ms(), 0.0);
        assert_eq!(ctl.predicted_prefix_end(), 0.0);

        ctl.dispatch_next().unwrap();
        let predicted_end = ctl.predicted_prefix_end();
        assert!(predicted_end > 0.0);
        // the engine finished 500 ms later than predicted
        let measured = predicted_end + 500.0;
        let drift = ctl.reconcile(&[], measured);
        assert!((drift - 500.0).abs() < 1e-6);
        assert!((ctl.drift_ms() - 500.0).abs() < 1e-6);
        assert!((ctl.stats().max_abs_drift_ms - 500.0).abs() < 1e-6);

        let live_before: Vec<usize> = {
            let fp = ctl.frozen_positions();
            ctl.plan().order[fp..]
                .iter()
                .map(|&j| ctl.jobs()[j].req_idx)
                .collect()
        };
        let stats = ctl.replan_from_drift().expect("drift replan runs");
        assert!(stats.evals > 0);
        // the origin is now the measured time, the prefix is compacted,
        // and the live suffix is preserved as a set
        assert_eq!(ctl.t0_ms(), measured);
        assert_eq!(ctl.frozen_batches(), 0);
        assert_eq!(ctl.drift_ms(), 0.0);
        assert_eq!(ctl.stats().drift_replans, 1);
        let mut live_after: Vec<usize> =
            ctl.jobs().iter().map(|j| j.req_idx).collect();
        let mut expected = live_before;
        expected.sort_unstable();
        live_after.sort_unstable();
        assert_eq!(live_after, expected);
        ctl.plan().validate(3).unwrap();
        // a second replan without a new reconcile is a no-op
        assert!(ctl.replan_from_drift().is_none());
    }

    #[test]
    fn reconcile_tracks_output_length_divergence() {
        use crate::coordinator::request::TaskType;
        let pred = predictor();
        let mut ctl =
            WaveController::new(&pred, params(2, 1), ReplanStrategy::Warm);
        let mk = |predicted: usize, actual: usize| Completion {
            id: 0,
            task: TaskType::Code,
            slo: Slo::E2e { e2e_ms: 1e9 },
            input_len: 10,
            predicted_lo: predicted,
            generated: actual,
            e2e_ms: 1.0,
            ttft_ms: 1.0,
            tpot_ms: 0.0,
            wait_ms: 0.0,
            batch_size: 1,
            text: None,
        };
        ctl.reconcile(&[mk(10, 14), mk(10, 4)], 0.0);
        assert_eq!(ctl.stats().reconciled_jobs, 2);
        // |14 − 10| + |4 − 10| = 10 -> mean 5
        assert_eq!(ctl.stats().avg_abs_lo_divergence(), 5.0);
    }

    #[test]
    fn zero_drift_threshold_run_matches_default_run_bit_for_bit() {
        // replan_drift_ms = 0 must be the historical event loop exactly —
        // reconciliation is bookkeeping only.
        let run = |opts: OnlineOpts| {
            let mut profile = by_name("qwen7b-v100x2-vllm").unwrap();
            profile.noise_std = 0.03; // noisy timing => nonzero drift
            let pred = profile.truth;
            let mut engine = SimEngine::new(profile, 2, 9);
            let mut reqs: Vec<Request> = (0..10)
                .map(|i| {
                    Request::synthetic(
                        i as u64,
                        TaskType::Code,
                        120 + 25 * i as usize,
                        10,
                        Slo::E2e { e2e_ms: 30_000.0 },
                    )
                })
                .collect();
            for (i, r) in reqs.iter_mut().enumerate() {
                r.arrival_ms = 300.0 * (i / 2) as f64;
            }
            let outs: Vec<usize> = reqs.iter().map(|r| r.output_len).collect();
            run_online_opts(
                &reqs,
                &outs,
                &mut engine,
                &pred,
                &params(2, 9),
                ReplanStrategy::Warm,
                opts,
            )
            .unwrap()
        };
        let base = run(OnlineOpts::default());
        let explicit = run(OnlineOpts { replan_drift_ms: 0.0, ..Default::default() });
        assert_eq!(base.completions.len(), explicit.completions.len());
        for (a, b) in base.completions.iter().zip(&explicit.completions) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.e2e_ms.to_bits(), b.e2e_ms.to_bits());
        }
        assert_eq!(base.stats.drift_replans, 0);
        // noisy timing was reconciled (diagnostics only)
        assert!(base.stats.max_abs_drift_ms > 0.0);
        // a tiny threshold on the same trace triggers drift replans and
        // still serves everything exactly once
        let drifted =
            run(OnlineOpts { replan_drift_ms: 1e-6, ..Default::default() });
        assert_eq!(drifted.completions.len(), 10);
        assert!(drifted.stats.drift_replans > 0);
        let ids: Vec<u64> =
            drifted.completions.iter().map(|c| c.id).collect();
        assert_eq!(ids, (0..10).collect::<Vec<u64>>());
    }

    #[test]
    fn kv_admission_rejects_job_larger_than_pool() {
        use crate::coordinator::kv::KvConfig;
        let pred = predictor();
        let p = SaParams { kv: KvConfig::hard(4), ..params(2, 0) };
        let mut ctl = WaveController::new(&pred, p, ReplanStrategy::Warm);
        let giant = Job {
            req_idx: 0,
            input_len: 100, // 7 blocks > 4-block pool
            output_len: 0,
            slo: Slo::E2e { e2e_ms: 1e9 },
        };
        let err = ctl.admit(&[giant]).unwrap_err();
        assert!(format!("{err}").contains("KV blocks"), "{err}");
    }

    #[test]
    fn saturated_controller_defers_and_then_serves_everything() {
        use crate::coordinator::kv::KvConfig;
        let mut profile = by_name("qwen7b-v100x2-vllm").unwrap();
        profile.noise_std = 0.0;
        let pred = profile.truth;
        // pool of 12 blocks; every request is 160+16 tokens = 11 blocks,
        // so batches are singletons and one undispatched job saturates.
        let kv = KvConfig::hard(12);
        let mut engine = SimEngine::new(profile, 4, 0);
        let mut reqs: Vec<Request> = (0..10)
            .map(|i| {
                Request::synthetic(
                    i as u64,
                    TaskType::Code,
                    160,
                    16,
                    Slo::E2e { e2e_ms: 1e9 },
                )
            })
            .collect();
        for (i, r) in reqs.iter_mut().enumerate() {
            // ~312 ms per singleton batch vs 200 ms inter-arrival: the
            // backlog builds past the pool and admissions get deferred.
            r.arrival_ms = 200.0 * i as f64;
        }
        let outs: Vec<usize> = reqs.iter().map(|r| r.output_len).collect();
        let out = run_online_opts(
            &reqs,
            &outs,
            &mut engine,
            &pred,
            &SaParams { kv, ..params(4, 7) },
            ReplanStrategy::Warm,
            OnlineOpts { compact_dispatched: true, ..Default::default() },
        )
        .unwrap();
        assert_eq!(out.completions.len(), 10);
        assert_eq!(out.stats.dispatched_jobs, 10);
        // every executed batch was a singleton (pool fits only one job)
        assert!(out.completions.iter().all(|c| c.batch_size == 1));
    }

    #[test]
    fn deferral_and_preemption_counters_stay_distinct() {
        // The pre-split accounting folded engine preemptions into the
        // deferral counter, double-counting a request that was deferred,
        // admitted, and then preempted. The counters are now distinct and
        // deferrals dedupe per request id across defer → admit → defer
        // cycles.
        let pred = predictor();
        let mut ctl =
            WaveController::new(&pred, params(2, 1), ReplanStrategy::Warm);
        assert!(ctl.note_deferral_of(7));
        assert!(!ctl.note_deferral_of(7), "re-deferral must not recount");
        assert!(ctl.note_deferral_of(9));
        assert_eq!(ctl.stats().deferrals, 2);
        ctl.note_preemptions(3);
        ctl.note_migrations(2);
        // preemptions and migrations land in their own counters — never
        // back into deferrals
        assert_eq!(ctl.stats().deferrals, 2);
        assert_eq!(ctl.stats().preemptions, 3);
        assert_eq!(ctl.stats().migrations, 2);
    }

    fn skewed_fleet_trace() -> (Vec<Request>, Vec<usize>) {
        // Round-robin sends even indices to instance 0 and odd to
        // instance 1. Evens are heavy (112+16 tokens = 8 blocks on a
        // 12-block pool — singleton batches, a few hundred ms each); odds
        // are tiny (12+4 tokens = 1 block, fast). Pairs arrive together
        // every 100 ms — far faster than instance 0 can serve — so its
        // backlog saturates and defers while instance 1 keeps ≤ 4 blocks
        // of backlog, leaving ≥ 8 blocks of headroom for a stolen heavy:
        // the work-stealing scenario.
        let reqs: Vec<Request> = (0..20)
            .map(|g| {
                let (input, output) =
                    if g % 2 == 0 { (112, 16) } else { (12, 4) };
                let mut r = Request::synthetic(
                    g as u64,
                    TaskType::Code,
                    input,
                    output,
                    Slo::E2e { e2e_ms: 60_000.0 },
                );
                r.arrival_ms = 100.0 * (g / 2) as f64;
                r
            })
            .collect();
        let outs: Vec<usize> = reqs.iter().map(|r| r.output_len).collect();
        (reqs, outs)
    }

    #[test]
    fn fleet_migration_sheds_to_idle_peer_and_is_deterministic() {
        use crate::coordinator::kv::KvConfig;
        let run = || {
            let mut profile = by_name("qwen7b-v100x2-vllm").unwrap();
            profile.noise_std = 0.0;
            let pred = profile.truth;
            let mut engines: Vec<Box<dyn Engine + Send>> = (0..2)
                .map(|i| {
                    Box::new(SimEngine::new(profile.clone(), 4, i as u64))
                        as Box<dyn Engine + Send>
                })
                .collect();
            let (reqs, outs) = skewed_fleet_trace();
            let sa =
                SaParams { kv: KvConfig::hard(12), ..params(4, 7) };
            run_online_fleet_migrating(
                &reqs,
                &outs,
                &mut engines,
                &pred,
                &sa,
                ReplanStrategy::Warm,
                OnlineOpts {
                    compact_dispatched: true,
                    migrate: true,
                    ..Default::default()
                },
            )
            .unwrap()
        };
        let (completions, outcomes) = run();
        // exactly-once completion across the fleet
        assert_eq!(completions.len(), 20);
        let ids: Vec<u64> = completions.iter().map(|c| c.id).collect();
        assert_eq!(ids, (0..20).collect::<Vec<u64>>());
        // the saturated heavy instance shed work to its idle peer
        let migrations: usize =
            outcomes.iter().map(|o| o.stats.migrations).sum();
        assert!(migrations >= 1, "no migration on a skewed fleet");
        // the saturated heavy queue (instance 0) is a shedding source
        assert!(outcomes[0].stats.migrations >= 1, "{:?}", outcomes[0].stats);
        // fleet-level dedup: each request counts at most one deferral
        let deferrals: usize =
            outcomes.iter().map(|o| o.stats.deferrals).sum();
        assert!(deferrals <= 20);
        // fixed seed ⇒ identical victim/target choices and completions
        let (c2, o2) = run();
        assert_eq!(completions.len(), c2.len());
        for (a, b) in completions.iter().zip(&c2) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.e2e_ms.to_bits(), b.e2e_ms.to_bits());
        }
        for (a, b) in outcomes.iter().zip(&o2) {
            assert_eq!(a.stats.migrations, b.stats.migrations);
            assert_eq!(a.stats.deferrals, b.stats.deferrals);
            assert_eq!(a.stats.dispatched_jobs, b.stats.dispatched_jobs);
        }
    }

    #[test]
    fn single_instance_fleet_never_migrates_and_replays_fleet_loop() {
        use crate::coordinator::kv::KvConfig;
        let mk_engine = || {
            let mut profile = by_name("qwen7b-v100x2-vllm").unwrap();
            profile.noise_std = 0.0;
            vec![Box::new(SimEngine::new(profile, 4, 0))
                as Box<dyn Engine + Send>]
        };
        let profile = by_name("qwen7b-v100x2-vllm").unwrap();
        let pred = profile.truth;
        let mut reqs: Vec<Request> = (0..10)
            .map(|i| {
                Request::synthetic(
                    i as u64,
                    TaskType::Code,
                    160,
                    16,
                    Slo::E2e { e2e_ms: 1e9 },
                )
            })
            .collect();
        for (i, r) in reqs.iter_mut().enumerate() {
            r.arrival_ms = 200.0 * i as f64;
        }
        let outs: Vec<usize> = reqs.iter().map(|r| r.output_len).collect();
        let sa = SaParams { kv: KvConfig::hard(12), ..params(4, 7) };
        let base_opts =
            OnlineOpts { compact_dispatched: true, ..Default::default() };
        let mut plain_engines = mk_engine();
        let (plain, _) = run_online_fleet_opts(
            &reqs,
            &outs,
            &mut plain_engines,
            &pred,
            &sa,
            ReplanStrategy::Warm,
            base_opts,
        )
        .unwrap();
        let mut mig_engines = mk_engine();
        let (migrating, outcomes) = run_online_fleet_migrating(
            &reqs,
            &outs,
            &mut mig_engines,
            &pred,
            &sa,
            ReplanStrategy::Warm,
            OnlineOpts { migrate: true, ..base_opts },
        )
        .unwrap();
        // no peer to steal from: migration never fires, and the
        // interleaved loop replays the independent fleet loop bit for bit
        assert_eq!(outcomes.len(), 1);
        assert_eq!(outcomes[0].stats.migrations, 0);
        assert_eq!(plain.len(), migrating.len());
        for (a, b) in plain.iter().zip(&migrating) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.e2e_ms.to_bits(), b.e2e_ms.to_bits());
            assert_eq!(a.wait_ms.to_bits(), b.wait_ms.to_bits());
        }
    }
}
