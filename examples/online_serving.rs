//! Online wave admission (ISSUE 2): serve a timed multi-SLO arrival trace
//! with warm-started SA replanning, and compare against the cold-restart
//! ablation at the *same* iteration budget.
//!
//! The trace mixes the paper's two SLO classes on different arrival
//! processes — steady Poisson chat traffic plus ON-OFF bursty code
//! traffic — so replanning has to fold bursts into an in-flight plan.
//! Reported per strategy: per-SLO-class attainment, measured G, replan
//! count and overhead, and the predicted objective of the final plan.
//!
//! The run closes with an **output-length divergence** study (ISSUE 5):
//! the same trace served with the engine sampling each request's *true*
//! decode length around its prediction (`σ ∈ {0, 0.2, 0.5}` lognormal),
//! with the drift-reconciling replan loop off vs on — per-class
//! attainment, measured G, drift-replan counts, and the mean
//! |actual − predicted| output divergence per row. Oracle output
//! predictions isolate the engine's divergence as the only
//! predicted-vs-actual gap.
//!
//! Before that, an **online objective fidelity** table (ISSUE 4):
//! the same warm-replanned trace evaluated on the closed-wave t = 0
//! timeline versus the arrival-aware timeline, reporting per-request
//! predicted-vs-executed waiting-time error. The arrival-aware timeline
//! models engine idle gaps and per-job arrival offsets, so its error
//! collapses to pure latency-model error.
//!
//! All seeds are printed; reruns are bit-identical.
//!
//!     cargo run --release --example online_serving

use slo_serve::bench::{fit_predictor_from_profile, warm_output_profiler};
use slo_serve::config::profiles::by_name;
use slo_serve::config::{OutputPrediction, SloTargets};
use slo_serve::coordinator::online::{
    run_online, run_online_opts, OnlineOpts, OnlineOutcome, ReplanStrategy,
};
use slo_serve::coordinator::predict_outputs;
use slo_serve::coordinator::predictor::{fit_lo_sigma, quantile_multiplier};
use slo_serve::coordinator::priority::annealing::SaParams;
use slo_serve::engine::sim::{DivergenceModel, SimEngine};
use slo_serve::metrics::{fmt, RunMetrics, Table};
use slo_serve::util::rng::Rng;
use slo_serve::workload::dataset::RequestFactory;
use slo_serve::workload::trace::{ArrivalProcess, ClassMix};

/// Mean / max absolute predicted-vs-executed waiting-time error (ms)
/// over the requests the outcome still tracks.
fn wait_error(outcome: &OnlineOutcome) -> (f64, f64) {
    let mut sum = 0.0f64;
    let mut max = 0.0f64;
    let mut n = 0usize;
    for p in &outcome.predicted {
        if let Ok(i) =
            outcome.completions.binary_search_by_key(&p.id, |c| c.id)
        {
            let err = (p.wait_ms - outcome.completions[i].wait_ms).abs();
            sum += err;
            max = max.max(err);
            n += 1;
        }
    }
    (if n == 0 { 0.0 } else { sum / n as f64 }, max)
}

fn main() -> anyhow::Result<()> {
    const SEED: u64 = 42;
    const REQUESTS: usize = 96;
    const MAX_BATCH: usize = 4;

    let profile = by_name("qwen7b-v100x2-vllm").unwrap();
    let slos = SloTargets::default().scaled(0.5); // strict enough to matter

    // Per-SLO-class arrival mix: steady chat stream + bursty code stream.
    let mix = ClassMix::chat_code(
        REQUESTS,
        ArrivalProcess::Poisson { rps: 6.0 },
        ArrivalProcess::OnOff { rps: 24.0, on_ms: 1_000.0, off_ms: 3_000.0 },
    );
    let mut factory = RequestFactory::new(SEED, slos);
    let mut trace_rng = Rng::new(SEED ^ 0x0411_13E);
    let trace = mix.generate(&mut factory, &mut trace_rng);

    let predictor = fit_predictor_from_profile(&profile, SEED);
    let profiler = warm_output_profiler(SEED, 200);
    let mut pred_rng = Rng::new(SEED ^ 0x007_FEED);
    let predicted = predict_outputs(
        &trace,
        &profiler,
        OutputPrediction::Profiler,
        &mut pred_rng,
        profile.max_total_tokens / 2,
    );
    let sa = SaParams { max_batch: MAX_BATCH, seed: SEED, ..Default::default() };

    println!(
        "== online admission: {} requests (chat poisson:6 + code \
         onoff:24:1000:3000), warm vs cold replanning ==\n",
        trace.len()
    );
    let mut t = Table::new(&[
        "replan",
        "attainment",
        "chat",
        "code",
        "G (req/s)",
        "replans",
        "avg replan ms",
        "total replan ms",
        "pred G (req/s)",
    ]);
    let mut summary = Vec::new();
    for strategy in [ReplanStrategy::Warm, ReplanStrategy::Cold] {
        let mut engine = SimEngine::new(profile.clone(), MAX_BATCH, SEED);
        let out = run_online(
            &trace, &predicted, &mut engine, &predictor, &sa, strategy,
        )?;
        let m = RunMetrics::from_completions(&out.completions);
        let by_task = RunMetrics::attainment_by_task(&out.completions);
        let att = |name: &str| {
            by_task
                .iter()
                .find(|(tt, _, _)| tt.name() == name)
                .map_or("-".into(), |(_, a, _)| fmt(*a))
        };
        t.row(vec![
            strategy.name().into(),
            fmt(m.attainment()),
            att("chat"),
            att("code"),
            fmt(m.g_req_per_s),
            out.stats.replans.to_string(),
            fmt(out.stats.avg_replan_ms()),
            fmt(out.stats.replan_ms_total),
            fmt(out.final_eval.g * 1000.0),
        ]);
        summary.push((strategy, m.g_req_per_s, out.stats.avg_replan_ms()));
    }
    print!("{}", t.render());

    let (_, warm_g, warm_ms) = summary[0];
    let (_, cold_g, cold_ms) = summary[1];
    println!(
        "\nwarm-started replanning at equal iteration budget: G {} req/s vs \
         cold {} req/s ({}), {:.3} ms vs {:.3} ms per replan",
        fmt(warm_g),
        fmt(cold_g),
        if warm_g >= cold_g { "warm >= cold" } else { "cold wins this trace" },
        warm_ms,
        cold_ms,
    );

    // -- Online objective fidelity (ISSUE 4): the same warm run evaluated
    // on the closed-wave t = 0 timeline vs the arrival-aware timeline.
    println!(
        "\n== online objective fidelity: predicted vs executed waits \
         (warm replanning, same trace) =="
    );
    let mut ft = Table::new(&[
        "timeline",
        "mean |wait err| ms",
        "max |wait err| ms",
        "attainment",
    ]);
    for arrival_aware in [false, true] {
        let mut engine = SimEngine::new(profile.clone(), MAX_BATCH, SEED);
        let out = run_online_opts(
            &trace,
            &predicted,
            &mut engine,
            &predictor,
            &sa,
            ReplanStrategy::Warm,
            OnlineOpts { arrival_aware, ..Default::default() },
        )?;
        let (mean_err, max_err) = wait_error(&out);
        let m = RunMetrics::from_completions(&out.completions);
        ft.row(vec![
            if arrival_aware { "arrival-aware".into() } else { "t = 0 (legacy)".into() },
            format!("{mean_err:.1}"),
            format!("{max_err:.1}"),
            fmt(m.attainment()),
        ]);
    }
    print!("{}", ft.render());
    println!(
        "(the arrival-aware timeline models idle gaps + arrival offsets; \
         its residual error is pure latency-model error)"
    );

    // -- Output-length divergence (ISSUE 5): actual decode lengths sampled
    // around the prediction, drift-replanning off vs on. Oracle
    // predictions make the engine's divergence the only gap.
    const DRIFT_MS: f64 = 250.0;
    println!(
        "\n== output-length divergence: σ ∈ {{0, 0.2, 0.5}} lognormal, \
         drift replanning (threshold {DRIFT_MS} ms) off vs on =="
    );
    let oracle: Vec<usize> = trace.iter().map(|r| r.output_len).collect();
    let mut residual_pairs: Vec<(usize, usize)> = Vec::new();
    let mut dt = Table::new(&[
        "sigma",
        "drift replan",
        "attainment",
        "chat",
        "code",
        "G (req/s)",
        "drift replans",
        "mean |dlo| tok",
        "max |drift| ms",
    ]);
    for &sigma in &[0.0, 0.2, 0.5] {
        let model = if sigma > 0.0 {
            DivergenceModel::Lognormal { sigma }
        } else {
            DivergenceModel::Off
        };
        for &drift_on in &[false, true] {
            let mut engine = SimEngine::new(profile.clone(), MAX_BATCH, SEED)
                .with_divergence(model);
            let out = run_online_opts(
                &trace,
                &oracle,
                &mut engine,
                &predictor,
                &sa,
                ReplanStrategy::Warm,
                OnlineOpts {
                    arrival_aware: true,
                    replan_drift_ms: if drift_on { DRIFT_MS } else { 0.0 },
                    ..Default::default()
                },
            )?;
            if sigma == 0.5 && !drift_on {
                residual_pairs = out
                    .completions
                    .iter()
                    .map(|c| (c.predicted_lo, c.generated))
                    .collect();
            }
            let m = RunMetrics::from_completions(&out.completions);
            let by_task = RunMetrics::attainment_by_task(&out.completions);
            let att = |name: &str| {
                by_task
                    .iter()
                    .find(|(tt, _, _)| tt.name() == name)
                    .map_or("-".into(), |(_, a, _)| fmt(*a))
            };
            dt.row(vec![
                format!("{sigma}"),
                if drift_on { "on".into() } else { "off".into() },
                fmt(m.attainment()),
                att("chat"),
                att("code"),
                fmt(m.g_req_per_s),
                out.stats.drift_replans.to_string(),
                format!("{:.1}", out.stats.avg_abs_lo_divergence()),
                format!("{:.0}", out.stats.max_abs_drift_ms),
            ]);
        }
    }
    print!("{}", dt.render());
    println!(
        "(drift replanning shifts the timeline origin to the measured \
         engine clock and warm-replans the live suffix once |drift| \
         reaches the threshold; the off rows ignore the drift entirely)"
    );
    // Close the loop on the quantile head: fit σ from the σ = 0.5 run's
    // own (predicted, actual) residuals and show the KV reservation
    // multiplier the recovered head implies at the 0.9 quantile.
    let fitted = fit_lo_sigma(&residual_pairs);
    println!(
        "quantile head fitted from the σ = 0.5 run's residuals: \
         σ̂ = {fitted:.3} (true 0.5) → reserve at q = 0.9 multiplies \
         predicted l_o by {:.2} (--kv-quantile 0.9)",
        quantile_multiplier(fitted, 0.9),
    );

    // -- Deadline-adaptive budgets (ISSUE 6): replans size their SA
    // iteration budget to the predicted execution window of the next
    // batch to dispatch. Report how much of the allotted window the
    // budgeted replans actually used.
    {
        let mut engine = SimEngine::new(profile.clone(), MAX_BATCH, SEED);
        let out = run_online_opts(
            &trace,
            &predicted,
            &mut engine,
            &predictor,
            &sa,
            ReplanStrategy::Warm,
            OnlineOpts {
                arrival_aware: true,
                adaptive_budget: true,
                ..Default::default()
            },
        )?;
        let s = &out.stats;
        println!(
            "\nbudget utilization (adaptive replans): {:.3} ms measured vs \
             {:.3} ms allotted across {} budgeted replans ({:.1}% of the \
             dispatch windows; wall {:.3} ms / cpu {:.3} ms total replan \
             overhead)",
            s.budget_spent_ms_total,
            s.budget_allotted_ms_total,
            s.budget_replans,
            100.0 * s.budget_utilization(),
            s.replan_ms_total,
            s.replan_cpu_ms_total,
        );
    }

    println!(
        "\nseeds: trace/search {SEED} (engine noise seed {SEED}); all \
         streams are deterministic — rerun reproduces these numbers bit \
         for bit"
    );
    Ok(())
}
