//! Arrival processes: turn request waves into timed traces.
//!
//! The paper's evaluation submits waves of concurrent requests (arrival at
//! t = 0); production front-ends see continuous traffic. This module
//! provides the arrival-time generators feeding the online admission path
//! ([`crate::coordinator::online`]) as well as the continuous-batching
//! baseline.
//!
//! # Trace formats
//!
//! A *trace* is a `Vec<Request>` sorted by `arrival_ms`, ids re-assigned
//! in arrival order (`0..n`). Arrival times are stamped by an
//! [`ArrivalProcess`]:
//!
//! * [`ArrivalProcess::Concurrent`] — all requests at t = 0 (the paper's
//!   closed-wave methodology; the online-equals-offline equivalence case).
//! * [`ArrivalProcess::Poisson`] — exponential inter-arrival gaps at
//!   `rps` requests/second (steady open-loop traffic).
//! * [`ArrivalProcess::Bursty`] — `burst` concurrent requests every
//!   `period_ms` (thundering-herd waves).
//! * [`ArrivalProcess::OnOff`] — an ON-OFF modulated Poisson process:
//!   Poisson at `rps` during `on_ms`-long phases, silence for `off_ms`
//!   between them (diurnal/bursty service traffic; the "Beyond Greedy
//!   Chunking" sliding-window setting).
//!
//! The textual spec accepted by [`ArrivalProcess::parse`] (CLI `--arrival`
//! flag, config files) is:
//!
//! ```text
//! concurrent | poisson:RPS | bursty:BURST:PERIOD_MS | onoff:RPS:ON_MS:OFF_MS
//! ```
//!
//! [`ClassMix`] builds multi-SLO-class traces: each class (task type ⇒ SLO
//! family) gets its own request count and arrival process; the per-class
//! streams are merged and sorted into one trace.
//!
//! # Determinism
//!
//! Every generator draws from an explicit caller-supplied [`Rng`]; equal
//! seeds produce bit-identical traces on every platform (the RNG is pure
//! u64 arithmetic). [`ClassMix::generate`] additionally forks one child
//! stream per class, so adding a class never perturbs the arrival times of
//! the classes before it. Record the seed alongside results — the bench
//! JSON and `ScheduleOutcome::seed` do — and a run can be reproduced
//! exactly.

use crate::coordinator::request::{Request, TaskType};
use crate::util::rng::Rng;
use crate::workload::dataset::RequestFactory;

/// Arrival-time process (see module docs for the trace formats).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// All requests arrive at t = 0 (the paper's wave methodology).
    Concurrent,
    /// Poisson arrivals at `rps` requests/second.
    Poisson { rps: f64 },
    /// Bursts of `burst` concurrent requests every `period_ms`.
    Bursty { burst: usize, period_ms: f64 },
    /// ON-OFF modulated Poisson: `rps` during `on_ms`-long ON phases,
    /// nothing during the `off_ms`-long OFF phases between them.
    OnOff { rps: f64, on_ms: f64, off_ms: f64 },
}

/// Trace spec: how many requests and how they arrive.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceSpec {
    pub n: usize,
    pub arrivals: ArrivalProcess,
}

impl TraceSpec {
    /// Generate a mixed-dataset trace: `n` requests from the factory's
    /// 50/50 chat+code wave, stamped by `arrivals` and sorted by arrival
    /// time with ids re-assigned in arrival order.
    pub fn generate(
        &self,
        factory: &mut RequestFactory,
        rng: &mut Rng,
    ) -> Vec<Request> {
        let mut reqs = factory.mixed_wave(self.n);
        self.arrivals.apply(&mut reqs, rng);
        finalize_trace(&mut reqs);
        reqs
    }
}

impl ArrivalProcess {
    /// Stamp arrival times onto a request wave (in place, preserving order).
    /// All processes emit non-decreasing times in slice order.
    pub fn apply(&self, requests: &mut [Request], rng: &mut Rng) {
        match *self {
            ArrivalProcess::Concurrent => {
                for r in requests.iter_mut() {
                    r.arrival_ms = 0.0;
                }
            }
            ArrivalProcess::Poisson { rps } => {
                assert!(rps > 0.0, "Poisson rate must be positive");
                let mut t = 0.0;
                for r in requests.iter_mut() {
                    t += rng.exponential(rps / 1000.0); // gaps in ms
                    r.arrival_ms = t;
                }
            }
            ArrivalProcess::Bursty { burst, period_ms } => {
                assert!(burst > 0);
                for (i, r) in requests.iter_mut().enumerate() {
                    r.arrival_ms = (i / burst) as f64 * period_ms;
                }
            }
            ArrivalProcess::OnOff { rps, on_ms, off_ms } => {
                assert!(rps > 0.0, "ON-phase rate must be positive");
                assert!(on_ms > 0.0, "ON phase must have positive length");
                assert!(off_ms >= 0.0);
                // Draw on an "ON-time" clock, then splice the OFF gaps in:
                // ON-time u maps to wall time by inserting one OFF period
                // per completed ON phase.
                let mut u = 0.0f64;
                for r in requests.iter_mut() {
                    u += rng.exponential(rps / 1000.0);
                    let phase = (u / on_ms).floor();
                    r.arrival_ms = phase * (on_ms + off_ms) + (u - phase * on_ms);
                }
            }
        }
    }

    /// Parse the textual spec (module docs):
    /// `concurrent | poisson:RPS | bursty:BURST:PERIOD_MS |
    /// onoff:RPS:ON_MS:OFF_MS`.
    pub fn parse(spec: &str) -> Result<ArrivalProcess, String> {
        let parts: Vec<&str> = spec.split(':').collect();
        let bad = || format!("bad arrival spec '{spec}'");
        // Finite-only: NaN/inf would slip past `<= 0.0` rejections (NaN
        // comparisons are false) and then panic in `apply` — or worse,
        // stamp NaN arrival times that spin the online event loop forever.
        let f = |s: &str| match s.parse::<f64>() {
            Ok(v) if v.is_finite() => Ok(v),
            _ => Err(bad()),
        };
        let u = |s: &str| s.parse::<usize>().map_err(|_| bad());
        match parts.as_slice() {
            ["concurrent"] => Ok(ArrivalProcess::Concurrent),
            ["poisson", rps] => {
                let rps = f(rps)?;
                if rps <= 0.0 {
                    return Err(bad());
                }
                Ok(ArrivalProcess::Poisson { rps })
            }
            ["bursty", burst, period] => {
                let burst = u(burst)?;
                let period_ms = f(period)?;
                if burst == 0 || period_ms <= 0.0 {
                    return Err(bad());
                }
                Ok(ArrivalProcess::Bursty { burst, period_ms })
            }
            ["onoff", rps, on, off] => {
                let (rps, on_ms, off_ms) = (f(rps)?, f(on)?, f(off)?);
                if rps <= 0.0 || on_ms <= 0.0 || off_ms < 0.0 {
                    return Err(bad());
                }
                Ok(ArrivalProcess::OnOff { rps, on_ms, off_ms })
            }
            _ => Err(bad()),
        }
    }
}

/// One SLO class of a [`ClassMix`]: a task type (which fixes the SLO
/// family — e2e for code, TTFT+TPOT for chat), a request count, and its
/// own arrival process.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClassSpec {
    pub task: TaskType,
    pub n: usize,
    pub arrivals: ArrivalProcess,
}

/// Per-SLO-class arrival mix: independent arrival streams per class,
/// merged into one trace (module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct ClassMix {
    pub classes: Vec<ClassSpec>,
}

impl ClassMix {
    /// The paper's 50/50 chat+code mix, with each class on its own
    /// arrival process.
    pub fn chat_code(
        n: usize,
        chat: ArrivalProcess,
        code: ArrivalProcess,
    ) -> ClassMix {
        ClassMix {
            classes: vec![
                ClassSpec { task: TaskType::Code, n: n.div_ceil(2), arrivals: code },
                ClassSpec { task: TaskType::Chat, n: n / 2, arrivals: chat },
            ],
        }
    }

    /// Total request count across classes.
    pub fn total(&self) -> usize {
        self.classes.iter().map(|c| c.n).sum()
    }

    /// Generate the merged trace: per class, draw `n` requests of its task
    /// type from the factory and stamp its arrival process using a forked
    /// child RNG stream (class `i` gets `rng.fork(i)`, so class streams
    /// are mutually independent and insertion-order stable); then merge
    /// all classes, sort by arrival time (stable: ties keep class order),
    /// and re-assign ids in arrival order.
    pub fn generate(
        &self,
        factory: &mut RequestFactory,
        rng: &mut Rng,
    ) -> Vec<Request> {
        let mut all: Vec<Request> = Vec::with_capacity(self.total());
        for (i, class) in self.classes.iter().enumerate() {
            let mut class_rng = rng.fork(i as u64);
            let mut reqs = factory.uniform_wave(class.n, class.task);
            class.arrivals.apply(&mut reqs, &mut class_rng);
            all.extend(reqs);
        }
        finalize_trace(&mut all);
        all
    }
}

/// Sort a stamped wave into trace form: ascending `arrival_ms` (stable;
/// NaN-safe via `total_cmp`) with ids re-assigned in arrival order.
pub fn finalize_trace(requests: &mut [Request]) {
    requests.sort_by(|a, b| a.arrival_ms.total_cmp(&b.arrival_ms));
    for (i, r) in requests.iter_mut().enumerate() {
        r.id = i as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SloTargets;
    use crate::workload::dataset::RequestFactory;

    fn wave(n: usize) -> Vec<Request> {
        RequestFactory::new(0, SloTargets::default()).mixed_wave(n)
    }

    #[test]
    fn concurrent_zeroes_arrivals() {
        let mut reqs = wave(5);
        let mut rng = Rng::new(0);
        ArrivalProcess::Concurrent.apply(&mut reqs, &mut rng);
        assert!(reqs.iter().all(|r| r.arrival_ms == 0.0));
    }

    #[test]
    fn poisson_is_monotone_with_correct_rate() {
        let mut reqs = wave(2000);
        let mut rng = Rng::new(1);
        ArrivalProcess::Poisson { rps: 10.0 }.apply(&mut reqs, &mut rng);
        for w in reqs.windows(2) {
            assert!(w[1].arrival_ms >= w[0].arrival_ms);
        }
        // 2000 requests at 10 rps ≈ 200 s span
        let span_s = reqs.last().unwrap().arrival_ms / 1000.0;
        assert!((span_s - 200.0).abs() < 20.0, "span {span_s}");
    }

    #[test]
    fn bursty_groups() {
        let mut reqs = wave(10);
        let mut rng = Rng::new(2);
        ArrivalProcess::Bursty { burst: 4, period_ms: 100.0 }
            .apply(&mut reqs, &mut rng);
        assert_eq!(reqs[0].arrival_ms, 0.0);
        assert_eq!(reqs[3].arrival_ms, 0.0);
        assert_eq!(reqs[4].arrival_ms, 100.0);
        assert_eq!(reqs[9].arrival_ms, 200.0);
    }

    #[test]
    fn onoff_is_monotone_and_skips_off_phases() {
        let mut reqs = wave(3000);
        let mut rng = Rng::new(3);
        let (on_ms, off_ms) = (500.0, 1500.0);
        ArrivalProcess::OnOff { rps: 20.0, on_ms, off_ms }
            .apply(&mut reqs, &mut rng);
        let cycle = on_ms + off_ms;
        for w in reqs.windows(2) {
            assert!(w[1].arrival_ms >= w[0].arrival_ms);
        }
        for r in &reqs {
            // every arrival lands inside an ON window
            let in_cycle = r.arrival_ms % cycle;
            assert!(
                in_cycle < on_ms,
                "arrival {} in OFF phase (offset {in_cycle})",
                r.arrival_ms
            );
        }
        // effective rate = rps · on/(on+off) = 5 rps -> 3000 reqs ≈ 600 s
        let span_s = reqs.last().unwrap().arrival_ms / 1000.0;
        assert!((span_s - 600.0).abs() < 80.0, "span {span_s}");
    }

    #[test]
    fn onoff_with_zero_off_matches_poisson_stream() {
        let mut a = wave(500);
        let mut b = a.clone();
        let mut rng_a = Rng::new(9);
        let mut rng_b = Rng::new(9);
        ArrivalProcess::OnOff { rps: 8.0, on_ms: 1000.0, off_ms: 0.0 }
            .apply(&mut a, &mut rng_a);
        ArrivalProcess::Poisson { rps: 8.0 }.apply(&mut b, &mut rng_b);
        for (x, y) in a.iter().zip(&b) {
            assert!((x.arrival_ms - y.arrival_ms).abs() < 1e-6);
        }
    }

    #[test]
    fn parse_roundtrip_and_rejects_garbage() {
        assert_eq!(
            ArrivalProcess::parse("concurrent"),
            Ok(ArrivalProcess::Concurrent)
        );
        assert_eq!(
            ArrivalProcess::parse("poisson:12.5"),
            Ok(ArrivalProcess::Poisson { rps: 12.5 })
        );
        assert_eq!(
            ArrivalProcess::parse("bursty:8:250"),
            Ok(ArrivalProcess::Bursty { burst: 8, period_ms: 250.0 })
        );
        assert_eq!(
            ArrivalProcess::parse("onoff:20:500:1500"),
            Ok(ArrivalProcess::OnOff {
                rps: 20.0,
                on_ms: 500.0,
                off_ms: 1500.0
            })
        );
        for bad in [
            "", "nope", "poisson", "poisson:0", "poisson:x", "poisson:nan",
            "poisson:inf", "bursty:0:100", "bursty:8:nan", "bursty:8:0",
            "onoff:nan:500:1500", "onoff:20:0:100", "onoff:20:100",
        ] {
            assert!(ArrivalProcess::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn trace_spec_generates_sorted_reid_trace() {
        let mut factory = RequestFactory::new(5, SloTargets::default());
        let mut rng = Rng::new(5);
        let spec =
            TraceSpec { n: 40, arrivals: ArrivalProcess::Poisson { rps: 20.0 } };
        let trace = spec.generate(&mut factory, &mut rng);
        assert_eq!(trace.len(), 40);
        for (i, w) in trace.windows(2).enumerate() {
            assert!(w[1].arrival_ms >= w[0].arrival_ms, "at {i}");
        }
        for (i, r) in trace.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
    }

    #[test]
    fn class_mix_merges_streams_deterministically() {
        let gen = |seed: u64| {
            let mut factory = RequestFactory::new(seed, SloTargets::default());
            let mut rng = Rng::new(seed);
            ClassMix::chat_code(
                30,
                ArrivalProcess::Poisson { rps: 15.0 },
                ArrivalProcess::OnOff {
                    rps: 30.0,
                    on_ms: 400.0,
                    off_ms: 800.0,
                },
            )
            .generate(&mut factory, &mut rng)
        };
        let a = gen(11);
        let b = gen(11);
        assert_eq!(a.len(), 30);
        assert_eq!(
            a.iter().filter(|r| r.task == TaskType::Code).count(),
            15
        );
        for w in a.windows(2) {
            assert!(w[1].arrival_ms >= w[0].arrival_ms);
        }
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.task, y.task);
            assert_eq!(x.input_len, y.input_len);
            assert_eq!(x.arrival_ms.to_bits(), y.arrival_ms.to_bits());
        }
        // different seed -> different trace
        let c = gen(12);
        assert!(a
            .iter()
            .zip(&c)
            .any(|(x, y)| x.arrival_ms.to_bits() != y.arrival_ms.to_bits()));
    }
}
