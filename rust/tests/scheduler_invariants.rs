//! Property-based integration tests over the scheduling stack: schedule
//! invariants under the SA search, assignment partition properties,
//! predicted-vs-simulated consistency, and baseline orderings.

use slo_serve::coordinator::gap::{branch_and_bound, certified_gap, BnbParams};
use slo_serve::coordinator::objective::{Evaluator, Job, Schedule};
use slo_serve::coordinator::policies::Policy;
use slo_serve::coordinator::predictor::LatencyPredictor;
use slo_serve::coordinator::priority::annealing::{priority_mapping, SaParams};
use slo_serve::coordinator::request::Slo;
use slo_serve::util::prop::check;
use slo_serve::util::rng::Rng;

fn random_jobs(rng: &mut Rng, n: usize) -> Vec<Job> {
    (0..n)
        .map(|i| Job {
            req_idx: i,
            input_len: 1 + rng.below(1500),
            output_len: 1 + rng.below(400),
            slo: if rng.chance(0.5) {
                Slo::E2e { e2e_ms: rng.uniform(1_000.0, 60_000.0) }
            } else {
                Slo::Interactive {
                    ttft_ms: rng.uniform(500.0, 15_000.0),
                    tpot_ms: rng.uniform(15.0, 60.0),
                }
            },
        })
        .collect()
}

#[test]
fn sa_schedules_always_valid_and_complete() {
    let pred = LatencyPredictor::paper_table2();
    check("SA output is a valid schedule", 60, |rng| {
        let n = 1 + rng.below(24);
        let max_batch = 1 + rng.below(6);
        let jobs = random_jobs(rng, n);
        let ev = Evaluator::new(&jobs, &pred);
        let params = SaParams {
            max_batch,
            seed: rng.next_u64(),
            t0: 200.0,
            iters_per_temp: 30,
            ..Default::default()
        };
        let res = priority_mapping(&ev, &params);
        res.schedule
            .validate(max_batch)
            .map_err(|e| format!("n={n} mb={max_batch}: {e}"))?;
        if res.schedule.len() != n {
            return Err(format!("lost jobs: {} != {n}", res.schedule.len()));
        }
        Ok(())
    });
}

#[test]
fn sa_never_below_both_seeds() {
    let pred = LatencyPredictor::paper_table2();
    check("SA >= max(fcfs seed, sorted seed)", 40, |rng| {
        let n = 2 + rng.below(16);
        let max_batch = 1 + rng.below(4);
        let jobs = random_jobs(rng, n);
        let ev = Evaluator::new(&jobs, &pred);
        let params = SaParams {
            max_batch,
            seed: rng.next_u64(),
            t0: 100.0,
            iters_per_temp: 20,
            ..Default::default()
        };
        let res = priority_mapping(&ev, &params);
        let fcfs = ev.eval(&Schedule::fcfs(n, max_batch));
        if res.eval.g < fcfs.g - 1e-12 {
            return Err(format!(
                "SA g={} < FCFS seed g={}",
                res.eval.g, fcfs.g
            ));
        }
        Ok(())
    });
}

#[test]
fn eval_consistent_under_batch_merging_when_costs_flat() {
    // With batch-insensitive costs (alpha=beta=0), merging batches can only
    // reduce waiting: a fully-batched schedule dominates singletons.
    let pred = LatencyPredictor::new(
        slo_serve::coordinator::predictor::PhaseCoeffs {
            alpha: 0.0, beta: 0.0, gamma: 1.0, delta: 0.0,
        },
        slo_serve::coordinator::predictor::PhaseCoeffs {
            alpha: 0.0, beta: 0.0, gamma: 0.0, delta: 1.0,
        },
    );
    check("flat costs: batched sum-e2e <= singleton sum-e2e", 50, |rng| {
        let n = 2 + rng.below(10);
        let jobs = random_jobs(rng, n);
        let ev = Evaluator::new(&jobs, &pred);
        let merged = ev.eval(&Schedule::from_order((0..n).collect(), n));
        let split = ev.eval(&Schedule::from_order((0..n).collect(), 1));
        if merged.total_e2e_ms > split.total_e2e_ms + 1e-9 {
            return Err(format!(
                "merged {} > split {}",
                merged.total_e2e_ms, split.total_e2e_ms
            ));
        }
        Ok(())
    });
}

#[test]
fn edf_golden_orders_by_slo_deadline() {
    // Golden ordering for the previously untested Edf baseline: along the
    // emitted priority sequence, deadlines (e2e bound; TTFT bound for
    // interactive jobs) are non-decreasing.
    let pred = LatencyPredictor::paper_table2();
    let deadline = |j: &Job| match j.slo {
        Slo::E2e { e2e_ms } => e2e_ms,
        Slo::Interactive { ttft_ms, .. } => ttft_ms,
    };
    check("Edf orders by SLO deadline", 60, |rng| {
        let n = 1 + rng.below(20);
        let max_batch = 1 + rng.below(4);
        let jobs = random_jobs(rng, n);
        let ev = Evaluator::new(&jobs, &pred);
        let (s, _) = Policy::Edf.plan(&ev, max_batch);
        s.validate(max_batch)?;
        for w in s.order.windows(2) {
            let (a, b) = (deadline(&jobs[w[0]]), deadline(&jobs[w[1]]));
            if a > b {
                return Err(format!(
                    "deadline {a} before {b} in {:?}",
                    s.order
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn mlfq_golden_orders_by_input_length() {
    // Golden ordering for the Mlfq baseline: FastServe's skip-join MLFQ
    // assigns queues by prompt length, so the emitted sequence is
    // non-decreasing in input length.
    let pred = LatencyPredictor::paper_table2();
    check("Mlfq orders by input length", 60, |rng| {
        let n = 1 + rng.below(20);
        let max_batch = 1 + rng.below(4);
        let jobs = random_jobs(rng, n);
        let ev = Evaluator::new(&jobs, &pred);
        let (s, _) = Policy::Mlfq.plan(&ev, max_batch);
        s.validate(max_batch)?;
        for w in s.order.windows(2) {
            let (a, b) = (jobs[w[0]].input_len, jobs[w[1]].input_len);
            if a > b {
                return Err(format!("input {a} before {b} in {:?}", s.order));
            }
        }
        Ok(())
    });
}

#[test]
fn exhaustive_is_optimal_and_sa_matches_it_at_small_n() {
    // At N ≤ 7 the exhaustive strawman enumerates the whole
    // (order × partition) space, so its G is the optimum: SA can never
    // beat it. Branch-and-bound at full budget must reproduce that
    // optimum byte-for-byte (invariant 13, docs/ARCHITECTURE.md), and
    // best-of-3 SA must land within the certified-gap tolerance of the
    // B&B bound — SA is a heuristic, so we assert the *certificate*
    // (gap ≤ ε against a proven bound) rather than exact equality.
    let pred = LatencyPredictor::paper_table2();
    let max_batch = 2;
    for seed in 0..5u64 {
        let mut rng = Rng::new(seed ^ 0x90_1D);
        let n = 4 + rng.below(4); // 4..=7
        let jobs = random_jobs(&mut rng, n);
        let ev = Evaluator::new(&jobs, &pred);
        let (ex, ex_stats) = Policy::Exhaustive.plan(&ev, max_batch);
        assert!(ex_stats.is_some(), "seed {seed}: exhaustive fell back");
        let g_ex = ev.eval(&ex).g;
        // invariant 13: B&B at full budget closes the instance on the
        // exhaustive optimum, bit for bit
        let bnb = branch_and_bound(
            &ev,
            &BnbParams { max_batch, ..BnbParams::default() },
        );
        assert!(bnb.closed, "seed {seed}: B&B failed to close n={n}");
        assert_eq!(
            bnb.eval.g.to_bits(),
            g_ex.to_bits(),
            "seed {seed} (n={n}): B&B optimum g={} != exhaustive g={g_ex}",
            bnb.eval.g
        );
        // best SA objective over three independent search seeds at a
        // boosted budget (≈25k evaluations over a ≤106k-state space)
        let mut g_sa_best = f64::NEG_INFINITY;
        for sa_seed in 0..3u64 {
            let sa_params = SaParams {
                seed: seed.wrapping_mul(31).wrapping_add(sa_seed),
                iters_per_temp: 400,
                ..SaParams::default()
            };
            let (sa, _) = Policy::SloAware(sa_params).plan(&ev, max_batch);
            let g_sa = ev.eval(&sa).g;
            // optimality: exhaustive dominates every SA schedule
            assert!(
                g_ex >= g_sa - 1e-12,
                "seed {seed}/{sa_seed}: exhaustive g={g_ex} below SA \
                 g={g_sa}"
            );
            g_sa_best = g_sa_best.max(g_sa);
        }
        // … and SA's certified gap against the B&B bound stays within
        // the CI gate's ε (empirically 0 at this size; 5% is the gate)
        let gap = certified_gap(g_sa_best, bnb.bound_g);
        assert!(
            gap <= 0.05,
            "seed {seed} (n={n}, mb={max_batch}): best SA g={g_sa_best} \
             has certified gap {gap:.4} vs bound g={}",
            bnb.bound_g
        );
    }
}

#[test]
fn policies_preserve_job_multiset() {
    let pred = LatencyPredictor::paper_table2();
    check("every policy emits a permutation", 40, |rng| {
        let n = 1 + rng.below(12);
        let max_batch = 1 + rng.below(4);
        let jobs = random_jobs(rng, n);
        let ev = Evaluator::new(&jobs, &pred);
        for policy in [
            Policy::Fcfs,
            Policy::Sjf,
            Policy::Edf,
            Policy::Mlfq,
        ] {
            let (s, _) = policy.plan(&ev, max_batch);
            s.validate(max_batch)
                .map_err(|e| format!("{}: {e}", policy.name()))?;
        }
        Ok(())
    });
}

#[test]
fn predicted_timeline_matches_noiseless_sim() {
    // The SA's internal execution model (Eqs. 10–11) must agree with the
    // simulated engine when noise is zero and batches are homogeneous
    // (the paper's per-request Eq. 16 charges each request its own
    // lengths; the physical batch steps at the batch max, so only
    // homogeneous batches are exactly representable — heterogeneous
    // batches carry a small, documented modeling gap).
    use slo_serve::config::profiles::by_name;
    use slo_serve::engine::sim::SimEngine;
    use slo_serve::engine::{Engine, EngineRequest};

    let mut profile = by_name("qwen7b-v100x2-vllm").unwrap();
    profile.noise_std = 0.0;
    let pred = profile.truth;
    check("Eq.11 timeline == noiseless sim", 25, |rng| {
        let n = 1 + rng.below(8);
        let max_batch = 1 + rng.below(4);
        let input_len = 1 + rng.below(800);
        let output_len = 2 + rng.below(100);
        let jobs: Vec<Job> = (0..n)
            .map(|i| Job {
                req_idx: i,
                input_len,
                output_len,
                slo: Slo::E2e { e2e_ms: 1e12 },
            })
            .collect();
        let ev = Evaluator::new(&jobs, &pred);
        let schedule = Schedule::fcfs(n, max_batch);
        let (_, timelines) = ev.eval_detailed(&schedule);

        let mut engine = SimEngine::new(profile.clone(), max_batch, 0);
        let mut measured = vec![0.0f64; n];
        for (_, start, size) in schedule.batch_spans() {
            let batch: Vec<EngineRequest> = schedule.order
                [start..start + size]
                .iter()
                .map(|&j| EngineRequest {
                    id: j as u64,
                    input_len: jobs[j].input_len,
                    max_new_tokens: jobs[j].output_len,
                    prompt: None,
                })
                .collect();
            for item in engine.run_batch(&batch).map_err(|e| e.to_string())? {
                measured[item.id as usize] = item.finish_ms;
            }
        }
        // The paper's Eq. 16 charges l_o decode steps; physically the
        // first token is produced by prefill, so the engine runs l_o - 1
        // steps. Prediction must exceed measurement by EXACTLY the final
        // per-token decode time (per preceding batch-wait accumulation,
        // each earlier batch contributes the same one-step surplus).
        for t in &timelines {
            let predicted = t.wait_ms + t.exec_ms;
            let actual = measured[t.job];
            let surplus_per_batch =
                pred.tpot_at(schedule.batches[t.batch], input_len + output_len);
            let expected_gap = surplus_per_batch * (t.batch + 1) as f64;
            let gap = predicted - actual;
            if (gap - expected_gap).abs() > 1e-3 * actual.max(1.0) {
                return Err(format!(
                    "job {}: predicted {predicted:.2} vs sim {actual:.2}; gap {gap:.3}                      != expected one-step surplus {expected_gap:.3}",
                    t.job
                ));
            }
        }
        Ok(())
    });
}
