//! `slo-serve bench-http`: in-process open-loop load generator for the
//! serving front door.
//!
//! Drives K concurrent simulated clients against a live [`FrontDoor`]
//! (simulated engines, real threads + queues): an initial burst of
//! `clients` concurrent arrivals plus an optional Poisson tail paced on
//! the wall clock, with per-class SLO traces from the paper's chat+code
//! mix. Open loop: arrivals never wait for completions, so saturation
//! shows up as queue growth and 429 rejections, not as a slowed
//! generator. The report is a flat JSON object — admission/e2e latency
//! histograms (p50/p99), per-class attainment, accepted/rejected/
//! deferred counts, handoffs, tokens/sec — written to stdout and
//! optionally a file; CI gates on it.

use anyhow::{anyhow, Result};

use crate::bench;
use crate::config::profiles::by_name;
use crate::config::SloTargets;
use crate::coordinator::kv::{KvConfig, KvMode, DEFAULT_BLOCK_TOKENS};
use crate::engine::sim::{DivergenceModel, PreemptConfig, SimEngine};
use crate::engine::Engine;
use crate::server::front::{FrontDoor, FrontDoorConfig, SubmitError};
use crate::util;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::dataset::RequestFactory;
use crate::workload::trace::{finalize_trace, ArrivalProcess, ClassMix};

/// Wall-clock drain allowance after the last submission (ms).
const DRAIN_TIMEOUT_MS: u64 = 120_000;

/// bench-http knobs (CLI flags map 1:1).
pub struct BenchHttpConfig {
    /// Concurrent simulated clients: the initial burst size and the
    /// session-id modulus.
    pub clients: usize,
    pub shards: usize,
    pub queue_depth: usize,
    pub max_batch: usize,
    /// Hardware profile name for the simulated engines.
    pub profile: String,
    pub seed: u64,
    /// Poisson tail duration (s); 0 disables the tail.
    pub duration_s: f64,
    /// Poisson tail rate (req/s across both classes); 0 disables.
    pub rps: f64,
    /// SLO scale factor (>1 loosens; the paper's knob).
    pub slo_scale: f64,
    /// SA iteration budget per temperature for the shard controllers.
    pub iters_per_temp: usize,
    pub handoff: bool,
    /// Submit a fraction of requests in streaming mode (exercises the
    /// step-trace relay under load).
    pub stream: bool,
    /// Override the profile's engine KV pool (MB); 0 keeps the profile
    /// value. Shrinking it is the saturation scenario's lever: decode
    /// growth under divergence exhausts the pool mid-batch.
    pub kv_pool_mb: f64,
    /// Output-length divergence spec for the engines
    /// (`off | lognormal:<σ> | quantile-trace:<σ>`).
    pub divergence: String,
    /// Preemption spec for the engines (`off | recompute | swap`).
    pub preempt: String,
    /// Host↔device link bandwidth for `preempt = swap` (GB/s).
    pub kv_swap_gbps: f64,
    /// Host swap-buffer capacity for `preempt = swap` (KV blocks).
    pub kv_host_blocks: u64,
    /// Chunked-prefill chunk size (tokens) for the engines and the shard
    /// planners' TTFT pricing; 0 = whole-prompt prefill (invariant 15's
    /// byte-for-byte default).
    pub chunk_tokens: usize,
    /// Sliding-window SA width for the shard planners; 0 = whole-schedule
    /// search.
    pub window: usize,
}

impl Default for BenchHttpConfig {
    fn default() -> BenchHttpConfig {
        BenchHttpConfig {
            clients: 200,
            shards: 2,
            queue_depth: 4096,
            max_batch: 8,
            profile: "qwen7b-v100x2-vllm".into(),
            seed: 42,
            duration_s: 0.0,
            rps: 0.0,
            slo_scale: 10.0,
            iters_per_temp: 10,
            handoff: true,
            stream: false,
            kv_pool_mb: 0.0,
            divergence: "off".into(),
            preempt: "off".into(),
            kv_swap_gbps: 8.0,
            kv_host_blocks: 1024,
            chunk_tokens: 0,
            window: 0,
        }
    }
}

/// Run the load test; returns the flat JSON report.
pub fn run(cfg: &BenchHttpConfig) -> Result<Json> {
    anyhow::ensure!(cfg.clients > 0, "need at least one client");
    let mut profile = by_name(&cfg.profile)
        .ok_or_else(|| anyhow!("unknown profile '{}'", cfg.profile))?;
    let predictor = bench::fit_predictor_from_profile(&profile, cfg.seed);
    if cfg.kv_pool_mb > 0.0 {
        // Saturation lever: a deliberately undersized engine pool so
        // divergence-driven decode growth exhausts it mid-batch.
        profile.kv_pool_mb = cfg.kv_pool_mb;
    }
    let divergence = DivergenceModel::parse(&cfg.divergence)
        .map_err(|e| anyhow!(e))?;
    let preempt = PreemptConfig::parse(
        &cfg.preempt,
        cfg.kv_swap_gbps,
        cfg.kv_host_blocks,
    )
    .map_err(|e| anyhow!(e))?;
    let shards = cfg.shards.max(1);
    let engines: Vec<Box<dyn Engine + Send>> = (0..shards)
        .map(|s| {
            Box::new(
                SimEngine::new(
                    profile.clone(),
                    cfg.max_batch,
                    cfg.seed ^ (s as u64).wrapping_mul(0xE531_7AB1),
                )
                .with_divergence(divergence)
                .with_preemption(preempt)
                .with_chunk_tokens(cfg.chunk_tokens),
            ) as Box<dyn Engine + Send>
        })
        .collect();
    let max_total = engines[0].max_total_tokens();

    // ---- trace: concurrent burst + optional Poisson tail, chat+code
    // mix with per-class SLOs scaled by the configured factor.
    let mut factory = RequestFactory::new(
        cfg.seed ^ 0xBE9C_4071,
        SloTargets::default().scaled(cfg.slo_scale),
    );
    let mut rng = Rng::new(cfg.seed ^ 0x70AD_5EED);
    let burst = ClassMix::chat_code(
        cfg.clients,
        ArrivalProcess::Concurrent,
        ArrivalProcess::Concurrent,
    );
    let mut trace = burst.generate(&mut factory, &mut rng);
    let n_tail = (cfg.rps * cfg.duration_s) as usize;
    if n_tail > 0 {
        let half = (cfg.rps / 2.0).max(f64::MIN_POSITIVE);
        let tail = ClassMix::chat_code(
            n_tail,
            ArrivalProcess::Poisson { rps: half },
            ArrivalProcess::Poisson { rps: half },
        );
        trace.extend(tail.generate(&mut factory, &mut rng.fork(1)));
        finalize_trace(&mut trace);
    }

    // ---- front door
    let mut door_cfg = FrontDoorConfig::new(predictor, max_total);
    door_cfg.shards = shards;
    door_cfg.queue_depth = cfg.queue_depth.max(1);
    door_cfg.handoff = cfg.handoff;
    door_cfg.stream_tokens = cfg.stream;
    door_cfg.sa.max_batch = cfg.max_batch;
    door_cfg.sa.iters_per_temp = cfg.iters_per_temp.max(1);
    door_cfg.sa.seed = cfg.seed;
    door_cfg.sa.chunk_tokens = cfg.chunk_tokens;
    door_cfg.sa.window = cfg.window;
    if cfg.kv_pool_mb > 0.0 {
        // Bind the shard planners to the shrunken pool too. The Eq. 20
        // utility discount makes the scheduler's block budget strictly
        // tighter than the engine's raw pool, so every SA-feasible batch
        // passes the engine's nominal pre-check — exhaustion can then
        // only come from divergence-driven decode growth, which is the
        // preemption path the saturation scenario exercises.
        door_cfg.sa.kv = KvConfig::from_pool_mb(
            profile.kv_pool_mb,
            &profile.mem,
            DEFAULT_BLOCK_TOKENS,
            KvMode::Hard,
        );
    }
    let door = FrontDoor::start(door_cfg, engines)?;

    // ---- open-loop submission paced on the wall clock
    let submitted = trace.len();
    let mut saturated_rejects = 0u64;
    let mut invalid_rejects = 0u64;
    let t_start = util::now_ms();
    for (i, mut r) in trace.into_iter().enumerate() {
        let target = t_start + r.arrival_ms;
        loop {
            let now = util::now_ms();
            if now >= target {
                break;
            }
            let gap = (target - now).min(5.0).max(0.1);
            std::thread::sleep(std::time::Duration::from_micros(
                (gap * 1000.0) as u64,
            ));
        }
        let session = (i % cfg.clients) as u64;
        // streaming mode: every 8th request subscribes to token events
        let stream = cfg.stream && i % 8 == 0;
        r.arrival_ms = 0.0; // the door stamps its own arrival clock
        match door.submit(session, r, stream) {
            Ok(handle) => drop(handle), // shard metrics are the record
            Err(SubmitError::Saturated { .. }) => saturated_rejects += 1,
            Err(SubmitError::Invalid(_)) => invalid_rejects += 1,
            Err(SubmitError::ShuttingDown) => {
                anyhow::bail!("front door shut down mid-bench")
            }
        }
    }
    let submit_wall_ms = util::now_ms() - t_start;

    // ---- drain and report
    let drained = door.wait_drained(DRAIN_TIMEOUT_MS);
    if drained {
        door.shutdown(); // join workers: final metrics snapshots land
    }
    let wall_s = (util::now_ms() - t_start) / 1000.0;
    let stats = door.stats_json();
    let tokens_out = stats.get("tokens_out").as_f64().unwrap_or(0.0);
    let mut report = stats;
    if let Json::Obj(map) = &mut report {
        map.insert("bench".into(), Json::str("bench-http"));
        map.insert("profile".into(), Json::str(cfg.profile.clone()));
        map.insert("clients".into(), Json::num(cfg.clients as f64));
        map.insert("n_shards".into(), Json::num(shards as f64));
        map.insert(
            "queue_depth".into(),
            Json::num(cfg.queue_depth as f64),
        );
        map.insert("max_batch".into(), Json::num(cfg.max_batch as f64));
        map.insert("seed".into(), Json::num(cfg.seed as f64));
        map.insert("duration_s".into(), Json::num(cfg.duration_s));
        map.insert("rps".into(), Json::num(cfg.rps));
        map.insert("slo_scale".into(), Json::num(cfg.slo_scale));
        map.insert(
            "iters_per_temp".into(),
            Json::num(cfg.iters_per_temp as f64),
        );
        map.insert("handoff_enabled".into(), Json::Bool(cfg.handoff));
        map.insert("kv_pool_mb".into(), Json::num(profile.kv_pool_mb));
        map.insert(
            "divergence".into(),
            Json::str(cfg.divergence.clone()),
        );
        map.insert("preempt".into(), Json::str(cfg.preempt.clone()));
        map.insert(
            "chunk_tokens".into(),
            Json::num(cfg.chunk_tokens as f64),
        );
        map.insert("window".into(), Json::num(cfg.window as f64));
        map.insert("submitted".into(), Json::num(submitted as f64));
        map.insert(
            "rejected_saturated".into(),
            Json::num(saturated_rejects as f64),
        );
        map.insert(
            "rejected_invalid".into(),
            Json::num(invalid_rejects as f64),
        );
        map.insert(
            "submit_wall_ms".into(),
            Json::num(submit_wall_ms),
        );
        map.insert("wall_s".into(), Json::num(wall_s));
        map.insert(
            "tokens_per_s".into(),
            Json::num(if wall_s > 0.0 { tokens_out / wall_s } else { 0.0 }),
        );
        map.insert("drained".into(), Json::Bool(drained));
    }
    if !drained {
        // A wedged shard would make shutdown() join forever; leak the
        // door instead and let the caller fail the run on `drained`.
        std::mem::forget(door);
    }
    Ok(report)
}
