//! Minimal JSON substrate (serde is unavailable offline — DESIGN.md §2).
//!
//! Implements the full JSON grammar (RFC 8259): parsing into a [`Json`]
//! value tree, serialization (compact and pretty), and typed accessors used
//! by the config system, the artifact manifest loader, and the TCP serving
//! protocol.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a BTreeMap for deterministic serialization.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {offset}: {msg}")]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl Json {
    // ---------------------------------------------------------- accessors

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 9e15 => {
                Some(*n as i64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` for missing/non-object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index lookup; `Json::Null` out of range.
    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // -------------------------------------------------------- constructors

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    // ------------------------------------------------------- serialization

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }

    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let bytes = text.as_bytes();
        let mut p = Parser { bytes, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        out.push_str("null"); // JSON has no Inf/NaN
    } else if n.fract() == 0.0 && n.abs() < 9e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // surrogate pair handling
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    let combined = 0x10000
                                        + (((cp - 0xD800) as u32) << 10)
                                        + (lo - 0xDC00) as u32;
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp as u32)
                            };
                            s.push(c.ok_or_else(
                                || self.err("invalid unicode escape"))?);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = &self.bytes[self.pos..];
                    let text = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = text.chars().next().unwrap();
                    if (c as u32) < 0x20 {
                        return Err(self.err("control char in string"));
                    }
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u16, ParseError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("short unicode escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u16::from_str_radix(hex, 16)
            .map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::str("hi"));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").idx(2).get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert_eq!(v.get("a").idx(0).as_i64(), Some(1));
    }

    #[test]
    fn escapes_roundtrip() {
        let original = Json::str("line1\nline2\t\"quoted\" \\ \u{1F600} é");
        let text = original.to_string_compact();
        assert_eq!(Json::parse(&text).unwrap(), original);
    }

    #[test]
    fn unicode_escape_parse() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::str("A"));
        // surrogate pair: U+1F600
        assert_eq!(Json::parse(r#""😀""#).unwrap(),
                   Json::str("\u{1F600}"));
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\":}", "tru", "1.2.3", "\"\\x\"",
                    "[1] extra", "{\"a\" 1}", "nul"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn deep_roundtrip() {
        let v = Json::obj(vec![
            ("nums", Json::arr((0..20).map(|i| Json::num(i as f64 * 0.5)))),
            ("nested", Json::obj(vec![
                ("flag", Json::Bool(true)),
                ("name", Json::str("slo-serve")),
                ("none", Json::Null),
            ])),
        ]);
        for text in [v.to_string_compact(), v.to_string_pretty()] {
            assert_eq!(Json::parse(&text).unwrap(), v);
        }
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::num(5.0).to_string_compact(), "5");
        assert_eq!(Json::num(5.25).to_string_compact(), "5.25");
    }

    #[test]
    fn accessor_defaults() {
        let v = Json::parse(r#"{"a": 1}"#).unwrap();
        assert!(v.get("missing").is_null());
        assert!(v.get("a").get("deeper").is_null());
        assert!(v.idx(3).is_null());
        assert_eq!(v.get("a").as_usize(), Some(1));
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Num(1.5).as_i64(), None);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::Arr(vec![]).to_string_pretty(), "[]");
    }

    #[test]
    fn parse_error_reports_offset() {
        let err = Json::parse("[1, x]").unwrap_err();
        assert_eq!(err.offset, 4);
    }
}
