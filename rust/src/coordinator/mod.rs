//! L3 coordinator: the paper's SLO-aware serving system.
//!
//! Submodules implement the architecture of Fig. 6:
//!
//! ```text
//!  requests ─> [profiler] ─> [predictor] ─> [priority mapper] ─> queues
//!                  │              │         (SA / exhaustive /      │
//!                  │              │          baselines)             ▼
//!                  └── output-len & memory models        [instances: engines]
//! ```
//!
//! * [`request`]    — task types, SLOs, lifecycle records.
//! * [`profiler`]   — output-length + memory + latency-sample profiling.
//! * [`predictor`]  — Eq. 14–19 latency model (least-squares fitted).
//! * [`kv`]         — Eq. 20 KV-block feasibility model (pool geometry +
//!   hard/soft enforcement + reserve/phased batch demand, threaded
//!   through the SA search).
//! * [`pred_table`] — per-wave (job, batch) prediction table feeding the
//!   SA hot path, including per-job KV-block footprints and arrival
//!   times.
//! * [`objective`]  — the G objective, schedule representation, the
//!   arrival-aware timeline ([`objective::TimelineOrigin`]), and the
//!   full + incremental evaluators.
//! * [`priority`]   — Algorithm 1 (SA) and the exhaustive strawman.
//! * [`gap`]        — branch-and-bound optimality certificates: exact
//!   optima to N ≈ 12–14 plus certified upper bounds beyond (the
//!   search-quality harness's ground truth).
//! * [`policies`]   — FCFS/SJF/EDF/MLFQ/index/threshold baselines +
//!   policy dispatch.
//! * [`scheduler`]  — Algorithm 2 multi-instance assignment.
//! * [`online`]     — online wave admission: warm-started SA replanning
//!   over timestamped arrival streams (the batch-to-streaming bridge).
//! * this module    — plan execution against engines and completion records.

pub mod gap;
pub mod kv;
pub mod objective;
pub mod online;
pub mod policies;
pub mod pred_table;
pub mod predictor;
pub mod priority;
pub mod profiler;
pub mod request;
pub mod scheduler;

use anyhow::Result;

use crate::config::OutputPrediction;
use crate::coordinator::profiler::RequestProfiler;
use crate::coordinator::request::{Completion, Request};
use crate::coordinator::scheduler::InstancePlan;
use crate::engine::{Engine, EngineRequest};
use crate::util::rng::Rng;

/// Produce output-length predictions for a request wave (the Fig. 9 knob).
///
/// * `Profiler` — sample the per-task Gaussian the profiler fitted from
///   completed requests.
/// * `Oracle { rel_err }` — ground truth perturbed by ±rel_err uniform
///   noise (the paper's 2.5% / 5% / 10% accuracy study).
pub fn predict_outputs(
    requests: &[Request],
    profiler: &RequestProfiler,
    mode: OutputPrediction,
    rng: &mut Rng,
    max_len: usize,
) -> Vec<usize> {
    requests
        .iter()
        .map(|r| match mode {
            OutputPrediction::Profiler => {
                profiler.predict_output(r.task, rng, max_len)
            }
            OutputPrediction::Oracle { rel_err } => {
                let noisy = r.output_len as f64
                    * rng.uniform(1.0 - rel_err, 1.0 + rel_err);
                (noisy.round().max(1.0) as usize).min(max_len.max(1))
            }
        })
        .collect()
}

/// Convert an engine [`crate::engine::ItemResult`] into a [`Completion`]
/// using the request's arrival time for waiting/e2e accounting.
/// `predicted_lo` is the output length the scheduler planned the request
/// at — paired with the engine's `generated` it makes actual-vs-predicted
/// output-length divergence observable per request.
pub(crate) fn to_completion(
    req: &Request,
    item: &crate::engine::ItemResult,
    predicted_lo: usize,
) -> Completion {
    Completion {
        id: req.id,
        task: req.task,
        slo: req.slo,
        input_len: req.input_len,
        predicted_lo,
        generated: item.generated,
        e2e_ms: item.finish_ms - req.arrival_ms,
        ttft_ms: item.first_token_ms - req.arrival_ms,
        tpot_ms: item.tpot_ms(),
        wait_ms: item.start_ms - req.arrival_ms,
        batch_size: item.batch_size,
        text: item.text.clone(),
    }
}

/// Execute per-instance plans on their engines (planned/static-batch mode,
/// the SLO-aware execution path). `engines[plan.instance]` runs each plan.
///
/// Feeds the profiler with actual output lengths so later waves predict
/// better (the paper's dynamic output-length modelling).
pub fn execute_plans(
    requests: &[Request],
    plans: &[InstancePlan],
    engines: &mut [Box<dyn Engine + Send>],
    profiler: &mut RequestProfiler,
) -> Result<Vec<Completion>> {
    assert!(plans.len() <= engines.len());
    let mut completions = Vec::with_capacity(requests.len());
    for plan in plans {
        let engine = &mut engines[plan.instance];
        for (_, start, size) in plan.schedule.batch_spans() {
            // member jobs carry both the request index and the predicted
            // output length the plan priced them at
            let members: Vec<&objective::Job> = plan.schedule.order
                [start..start + size]
                .iter()
                .map(|&j| &plan.jobs[j])
                .collect();
            let batch: Vec<EngineRequest> = members
                .iter()
                .map(|job| {
                    let r = &requests[job.req_idx];
                    EngineRequest {
                        id: r.id,
                        input_len: r.input_len,
                        max_new_tokens: r.output_len,
                        prompt: r.prompt.clone(),
                    }
                })
                .collect();
            let items = engine.run_batch(&batch)?;
            for (job, item) in members.iter().zip(&items) {
                let req = &requests[job.req_idx];
                profiler.observe_output(req.task, item.generated);
                completions.push(to_completion(req, item, job.output_len));
            }
        }
    }
    completions.sort_by_key(|c| c.id);
    Ok(completions)
}

/// Execute the FCFS continuous-batching baseline on simulated engines
/// (arrival-ordered, no SLO awareness). Requests are split across engines
/// round-robin by index — the load balancing a vLLM fleet front-end applies.
pub fn execute_fcfs_continuous(
    requests: &[Request],
    engines: &mut [crate::engine::sim::SimEngine],
    profiler: &mut RequestProfiler,
) -> Result<Vec<Completion>> {
    let n_inst = engines.len().max(1);
    let mut per_engine: Vec<Vec<(f64, EngineRequest)>> =
        vec![Vec::new(); n_inst];
    for (i, r) in requests.iter().enumerate() {
        per_engine[i % n_inst].push((
            r.arrival_ms,
            EngineRequest {
                id: r.id,
                input_len: r.input_len,
                max_new_tokens: r.output_len,
                prompt: None,
            },
        ));
    }
    let mut completions = Vec::with_capacity(requests.len());
    let by_id: std::collections::HashMap<u64, &Request> =
        requests.iter().map(|r| (r.id, r)).collect();
    for (engine, arrivals) in engines.iter_mut().zip(&mut per_engine) {
        arrivals.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let items = engine.run_continuous(arrivals)?;
        for item in items {
            let req = by_id[&item.id];
            profiler.observe_output(req.task, item.generated);
            // FCFS plans nothing: its "prediction" is the nominal budget
            completions.push(to_completion(req, &item, req.output_len));
        }
    }
    completions.sort_by_key(|c| c.id);
    Ok(completions)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::profiles::by_name;
    use crate::coordinator::predictor::LatencyPredictor;
    use crate::coordinator::priority::annealing::SaParams;
    use crate::coordinator::profiler::MemoryModel;
    use crate::coordinator::request::{Slo, TaskType};
    use crate::coordinator::scheduler::{schedule, InstanceInfo};
    use crate::engine::sim::SimEngine;

    fn wave(n: usize) -> Vec<Request> {
        (0..n)
            .map(|i| {
                Request::synthetic(
                    i as u64,
                    if i % 2 == 0 { TaskType::Code } else { TaskType::Chat },
                    200 + 13 * i,
                    20 + 7 * i,
                    if i % 2 == 0 {
                        Slo::E2e { e2e_ms: 30_000.0 }
                    } else {
                        Slo::Interactive { ttft_ms: 10_000.0, tpot_ms: 50.0 }
                    },
                )
            })
            .collect()
    }

    #[test]
    fn predict_outputs_oracle_accuracy() {
        let reqs = wave(50);
        let profiler = RequestProfiler::new();
        let mut rng = Rng::new(0);
        let preds = predict_outputs(
            &reqs,
            &profiler,
            OutputPrediction::Oracle { rel_err: 0.05 },
            &mut rng,
            10_000,
        );
        for (p, r) in preds.iter().zip(&reqs) {
            let rel = (*p as f64 - r.output_len as f64).abs()
                / r.output_len as f64;
            assert!(rel <= 0.06, "pred {p} truth {} rel {rel}", r.output_len);
        }
        // exact oracle
        let exact = predict_outputs(
            &reqs,
            &profiler,
            OutputPrediction::Oracle { rel_err: 0.0 },
            &mut rng,
            10_000,
        );
        assert_eq!(
            exact,
            reqs.iter().map(|r| r.output_len).collect::<Vec<_>>()
        );
    }

    #[test]
    fn end_to_end_planned_execution() {
        let reqs = wave(8);
        let mut profiler = RequestProfiler::new();
        let mut rng = Rng::new(1);
        let preds = predict_outputs(
            &reqs,
            &profiler,
            OutputPrediction::Oracle { rel_err: 0.0 },
            &mut rng,
            2000,
        );
        let predictor = LatencyPredictor::paper_table2();
        let outcome = schedule(
            &reqs,
            &preds,
            &[InstanceInfo { id: 0, mem_mb: 16_000.0 }],
            &predictor,
            &MemoryModel::default(),
            &SaParams::with_max_batch(4),
        )
        .unwrap();
        let mut engines: Vec<Box<dyn Engine + Send>> = vec![Box::new(
            SimEngine::new(by_name("qwen7b-v100x2-vllm").unwrap(), 4, 0),
        )];
        let completions = execute_plans(
            &reqs,
            &outcome.plans,
            &mut engines,
            &mut profiler,
        )
        .unwrap();
        assert_eq!(completions.len(), 8);
        for c in &completions {
            assert!(c.e2e_ms > 0.0);
            assert!(c.ttft_ms <= c.e2e_ms + 1e-9);
            assert!(c.wait_ms >= 0.0);
            assert!(c.generated > 0);
        }
        // profiler learned output lengths
        assert!(profiler.output_model(TaskType::Code).unwrap().count() >= 4);
    }

    #[test]
    fn fcfs_continuous_baseline_runs() {
        let reqs = wave(10);
        let mut profiler = RequestProfiler::new();
        let mut engines = vec![SimEngine::new(
            by_name("qwen7b-v100x2-vllm").unwrap(),
            4,
            0,
        )];
        let completions =
            execute_fcfs_continuous(&reqs, &mut engines, &mut profiler)
                .unwrap();
        assert_eq!(completions.len(), 10);
        assert!(completions.windows(2).all(|w| w[0].id < w[1].id));
    }

    #[test]
    fn multi_instance_split() {
        let reqs = wave(12);
        let mut profiler = RequestProfiler::new();
        let mut engines: Vec<SimEngine> = (0..3)
            .map(|i| {
                SimEngine::new(
                    by_name("qwen7b-v100x2-vllm").unwrap(),
                    4,
                    i as u64,
                )
            })
            .collect();
        let completions =
            execute_fcfs_continuous(&reqs, &mut engines, &mut profiler)
                .unwrap();
        assert_eq!(completions.len(), 12);
    }
}
