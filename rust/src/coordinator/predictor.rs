//! Latency predictor (paper §4.2, Eqs. 14–19).
//!
//! Prefill time and per-token decode time are multiple linear regressions
//! with an interaction term:
//!
//! ```text
//! t_p(b, l_i)  = α_p·b·l_i + β_p·b + γ_p·l_i + δ_p          (Eq. 14)
//! τ_d(b, l_a)  = α_d·b·l_a + β_d·b + γ_d·l_a + δ_d          (Eq. 15)
//! t_d(b, l_i, l_o) = Σ_{k=1..l_o} τ_d(b, l_i + k)           (Eq. 16)
//! ```
//!
//! The decode sum has a closed form (arithmetic series), making e2e/TTFT/
//! TPOT prediction O(1). This matters: `calculateG` inside the simulated-
//! annealing loop is the scheduler's hot path (DESIGN.md §10).
//!
//! Coefficients are fitted from profiling samples with ordinary least
//! squares ([`fit_phase`]), exactly as §4.2 prescribes.

use crate::util::stats::{least_squares, normal_quantile, r_squared};

/// Fitting coefficients for one phase (Eq. 14 / Eq. 15).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PhaseCoeffs {
    pub alpha: f64,
    pub beta: f64,
    pub gamma: f64,
    pub delta: f64,
}

impl PhaseCoeffs {
    pub const ZERO: PhaseCoeffs =
        PhaseCoeffs { alpha: 0.0, beta: 0.0, gamma: 0.0, delta: 0.0 };

    /// Evaluate `α·b·l + β·b + γ·l + δ`.
    #[inline]
    pub fn eval(&self, b: f64, l: f64) -> f64 {
        self.alpha * b * l + self.beta * b + self.gamma * l + self.delta
    }

    /// Multiply every coefficient (used for hardware-profile scaling and the
    /// Fig. 10 perturbation study).
    pub fn scaled(&self, factor: f64) -> PhaseCoeffs {
        PhaseCoeffs {
            alpha: self.alpha * factor,
            beta: self.beta * factor,
            gamma: self.gamma * factor,
            delta: self.delta * factor,
        }
    }

    /// Perturb one coefficient by a relative factor (Fig. 10).
    pub fn perturbed(&self, which: Coeff, rel: f64) -> PhaseCoeffs {
        let mut c = *self;
        match which {
            Coeff::Alpha => c.alpha *= 1.0 + rel,
            Coeff::Beta => c.beta *= 1.0 + rel,
            Coeff::Gamma => c.gamma *= 1.0 + rel,
            Coeff::Delta => c.delta *= 1.0 + rel,
        }
        c
    }
}

/// Coefficient selector for sensitivity studies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Coeff {
    Alpha,
    Beta,
    Gamma,
    Delta,
}

impl Coeff {
    pub const ALL: [Coeff; 4] =
        [Coeff::Alpha, Coeff::Beta, Coeff::Gamma, Coeff::Delta];

    pub fn name(&self) -> &'static str {
        match self {
            Coeff::Alpha => "alpha",
            Coeff::Beta => "beta",
            Coeff::Gamma => "gamma",
            Coeff::Delta => "delta",
        }
    }
}

/// One profiling observation: measured phase latency at (batch, length).
#[derive(Debug, Clone, Copy)]
pub struct PhaseSample {
    pub batch: usize,
    pub len: usize,
    /// Measured prefill time (ms) or per-token decode time (ms).
    pub ms: f64,
}

/// Fit Eq. 14/15 coefficients from samples via least squares.
/// Returns `(coeffs, r²)`; None if the design matrix is degenerate
/// (e.g. all samples at one batch size).
pub fn fit_phase(samples: &[PhaseSample]) -> Option<(PhaseCoeffs, f64)> {
    if samples.len() < 4 {
        return None;
    }
    let rows: Vec<Vec<f64>> = samples
        .iter()
        .map(|s| {
            let b = s.batch as f64;
            let l = s.len as f64;
            vec![b * l, b, l, 1.0]
        })
        .collect();
    let y: Vec<f64> = samples.iter().map(|s| s.ms).collect();
    let beta = least_squares(&rows, &y)?;
    let coeffs = PhaseCoeffs {
        alpha: beta[0],
        beta: beta[1],
        gamma: beta[2],
        delta: beta[3],
    };
    let predicted: Vec<f64> = samples
        .iter()
        .map(|s| coeffs.eval(s.batch as f64, s.len as f64))
        .collect();
    Some((coeffs, r_squared(&predicted, &y)))
}

/// The latency predictor used by the priority mapper and the scheduler.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyPredictor {
    pub prefill: PhaseCoeffs,
    pub decode: PhaseCoeffs,
    /// **Quantile head**: lognormal σ of the output-length residuals
    /// `ln(actual_lo / predicted_lo)`, fitted from profiling residuals
    /// ([`fit_lo_sigma`]). `0.0` (the default) means the point prediction
    /// is treated as exact and every quantile collapses onto it —
    /// bit-identical to the pre-quantile predictor. A positive σ lets the
    /// KV layer reserve at a conservative output-length quantile
    /// ([`LatencyPredictor::quantile`]) while the latency objective keeps
    /// pricing the mean prediction — separating latency optimism from
    /// memory safety.
    pub lo_sigma: f64,
}

/// Predicted phase latencies for one request at a given batch size.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PredictedLatency {
    /// Execution e2e (Eq. 17) — excludes waiting time.
    pub exec_ms: f64,
    /// Prefill time (Eq. 18).
    pub prefill_ms: f64,
    /// Mean per-output-token decode time (Eq. 19).
    pub tpot_ms: f64,
}

impl LatencyPredictor {
    pub fn new(prefill: PhaseCoeffs, decode: PhaseCoeffs) -> Self {
        LatencyPredictor { prefill, decode, lo_sigma: 0.0 }
    }

    /// This predictor with the quantile head's residual σ set (see the
    /// `lo_sigma` field docs). `0.0` restores the exact-point behaviour.
    pub fn with_lo_sigma(mut self, lo_sigma: f64) -> Self {
        self.lo_sigma = lo_sigma.max(0.0);
        self
    }

    /// Paper Table 2 coefficients (Qwen2.5-7B on 2×V100, ms units).
    pub fn paper_table2() -> Self {
        LatencyPredictor {
            prefill: PhaseCoeffs {
                alpha: 0.1,
                beta: 5.7,
                gamma: 0.01,
                delta: 43.67,
            },
            decode: PhaseCoeffs {
                alpha: 0.0002,
                beta: 0.275,
                gamma: 0.00088,
                delta: 15.85,
            },
            lo_sigma: 0.0,
        }
    }

    /// Quantile-head multiplier at quantile `q`: `exp(σ·Φ⁻¹(q))` on the
    /// fitted lognormal residual model. Returns exactly `1.0` at the
    /// median or when no residual model is fitted (`lo_sigma == 0`) — the
    /// bit-identity escape hatch every pre-quantile caller relies on.
    /// `q` is clamped to (0, 1) exclusive so the multiplier stays finite.
    #[inline]
    pub fn quantile(&self, q: f64) -> f64 {
        quantile_multiplier(self.lo_sigma, q)
    }

    /// Conservative output length at quantile `q`: the point prediction
    /// scaled by the quantile-head multiplier, rounded up (never below the
    /// point prediction for q ≥ 0.5). Equals `predicted_lo` verbatim when
    /// the head is unfitted — the `lo_q` column then *is* the mean column.
    #[inline]
    pub fn lo_quantile(&self, predicted_lo: usize, q: f64) -> usize {
        let m = self.quantile(q);
        if m == 1.0 {
            return predicted_lo;
        }
        (predicted_lo as f64 * m).ceil() as usize
    }

    /// Eq. 14: prefill latency (ms).
    #[inline]
    pub fn prefill_ms(&self, batch: usize, input_len: usize) -> f64 {
        self.prefill.eval(batch as f64, input_len as f64)
    }

    /// Eq. 15: per-token decode latency at accumulated length `l_a` (ms).
    #[inline]
    pub fn tpot_at(&self, batch: usize, accumulated_len: usize) -> f64 {
        self.decode.eval(batch as f64, accumulated_len as f64)
    }

    /// Total prefill latency when the prompt is split into
    /// `chunk_tokens`-sized chunks, each executed as a batch-of-1 prefill
    /// call (the chunked-prefill engine's pricing): the sum of Eq. 14 over
    /// `ceil(input/chunk)` chunks, the last covering the remainder.
    /// `chunk_tokens == 0` means chunking is off and falls back to the
    /// whole-prompt `prefill_ms(1, input_len)`.
    pub fn chunked_prefill_ms(
        &self,
        input_len: usize,
        chunk_tokens: usize,
    ) -> f64 {
        if chunk_tokens == 0 || input_len <= chunk_tokens {
            return self.prefill_ms(1, input_len);
        }
        let full = input_len / chunk_tokens;
        let rem = input_len % chunk_tokens;
        // Sum identical full-chunk terms via one eval to keep it O(1);
        // addition order matches the naive loop (all full chunks are
        // bit-equal terms, so k·t is exact when t·k has no rounding —
        // we accumulate iteratively to stay bit-identical to the engine.
        let t_full = self.prefill_ms(1, chunk_tokens);
        let mut total = 0.0;
        for _ in 0..full {
            total += t_full;
        }
        if rem > 0 {
            total += self.prefill_ms(1, rem);
        }
        total
    }

    /// Eq. 16 in closed form:
    ///
    /// Σ_{k=1..lo} [α·b·(li+k) + β·b + γ·(li+k) + δ]
    ///   = lo·(β·b + δ) + (α·b + γ)·(lo·li + lo·(lo+1)/2)
    #[inline]
    pub fn decode_total_ms(
        &self,
        batch: usize,
        input_len: usize,
        output_len: usize,
    ) -> f64 {
        let b = batch as f64;
        let li = input_len as f64;
        let lo = output_len as f64;
        let d = &self.decode;
        lo * (d.beta * b + d.delta)
            + (d.alpha * b + d.gamma) * (lo * li + lo * (lo + 1.0) * 0.5)
    }

    /// Eqs. 17–19 bundled: predicted exec/prefill/TPOT (no waiting time).
    #[inline]
    pub fn predict(
        &self,
        batch: usize,
        input_len: usize,
        output_len: usize,
    ) -> PredictedLatency {
        let prefill_ms = self.prefill_ms(batch, input_len);
        let decode_ms = self.decode_total_ms(batch, input_len, output_len);
        let tpot_ms = if output_len > 0 {
            decode_ms / output_len as f64
        } else {
            0.0
        };
        PredictedLatency { exec_ms: prefill_ms + decode_ms, prefill_ms, tpot_ms }
    }

    /// Fit both phases from profiling data (§4.2 workflow).
    pub fn fit(
        prefill_samples: &[PhaseSample],
        decode_samples: &[PhaseSample],
    ) -> Option<(Self, f64, f64)> {
        let (prefill, r2_p) = fit_phase(prefill_samples)?;
        let (decode, r2_d) = fit_phase(decode_samples)?;
        Some((LatencyPredictor { prefill, decode, lo_sigma: 0.0 }, r2_p, r2_d))
    }
}

/// Quantile multiplier of a lognormal residual model: `exp(σ·Φ⁻¹(q))`,
/// exactly `1.0` at `σ = 0` or `q = 0.5`. The single definition behind
/// [`LatencyPredictor::quantile`] and the CLI's `--kv-quantile`.
#[inline]
pub fn quantile_multiplier(sigma: f64, q: f64) -> f64 {
    if sigma == 0.0 || q == 0.5 {
        return 1.0;
    }
    let q = q.clamp(1e-9, 1.0 - 1e-9);
    (sigma * normal_quantile(q)).exp()
}

/// Fit the quantile head's lognormal σ from observed
/// `(predicted_lo, actual_lo)` residual pairs: the standard deviation of
/// `ln(actual / predicted)` over pairs where both sides are positive.
/// Returns `0.0` (the exact-point head) when fewer than two usable pairs
/// exist — an unfitted head must never inflate reservations.
pub fn fit_lo_sigma(pairs: &[(usize, usize)]) -> f64 {
    let logs: Vec<f64> = pairs
        .iter()
        .filter(|&&(p, a)| p > 0 && a > 0)
        .map(|&(p, a)| (a as f64 / p as f64).ln())
        .collect();
    if logs.len() < 2 {
        return 0.0;
    }
    crate::util::stats::std_dev(&logs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn p() -> LatencyPredictor {
        LatencyPredictor::paper_table2()
    }

    #[test]
    fn prefill_matches_eq14() {
        // α_p·b·l + β_p·b + γ_p·l + δ_p with Table 2 values
        let got = p().prefill_ms(4, 500);
        let want = 0.1 * 4.0 * 500.0 + 5.7 * 4.0 + 0.01 * 500.0 + 43.67;
        assert!((got - want).abs() < 1e-9);
    }

    #[test]
    fn decode_closed_form_matches_naive_sum() {
        let pred = p();
        for &(b, li, lo) in
            &[(1usize, 10usize, 5usize), (4, 100, 64), (8, 1999, 1), (2, 0, 300)]
        {
            let naive: f64 =
                (1..=lo).map(|k| pred.tpot_at(b, li + k)).sum();
            let closed = pred.decode_total_ms(b, li, lo);
            assert!(
                (naive - closed).abs() < 1e-6,
                "b={b} li={li} lo={lo}: naive={naive} closed={closed}"
            );
        }
    }

    #[test]
    fn predict_bundles_eq17_to_19() {
        let pred = p();
        let out = pred.predict(2, 128, 64);
        assert!((out.exec_ms
            - (pred.prefill_ms(2, 128) + pred.decode_total_ms(2, 128, 64)))
            .abs()
            < 1e-9);
        assert!((out.tpot_ms - pred.decode_total_ms(2, 128, 64) / 64.0).abs()
            < 1e-9);
    }

    #[test]
    fn predict_zero_output() {
        let out = p().predict(1, 100, 0);
        assert_eq!(out.tpot_ms, 0.0);
        assert!((out.exec_ms - out.prefill_ms).abs() < 1e-12);
    }

    #[test]
    fn latency_monotonic_in_batch_and_len() {
        let pred = p();
        assert!(pred.prefill_ms(2, 100) < pred.prefill_ms(4, 100));
        assert!(pred.prefill_ms(2, 100) < pred.prefill_ms(2, 200));
        assert!(pred.decode_total_ms(1, 100, 10)
            < pred.decode_total_ms(1, 100, 20));
    }

    #[test]
    fn fit_recovers_table2() {
        // Generate noiseless samples from Table 2 and re-fit (§4.2).
        let truth = p();
        let mut prefill = Vec::new();
        let mut decode = Vec::new();
        for &b in &[1usize, 2, 4, 8, 16, 32] {
            for &l in &[100usize, 500, 1000, 2000, 4000, 8000] {
                prefill.push(PhaseSample {
                    batch: b,
                    len: l,
                    ms: truth.prefill.eval(b as f64, l as f64),
                });
                decode.push(PhaseSample {
                    batch: b,
                    len: l,
                    ms: truth.decode.eval(b as f64, l as f64),
                });
            }
        }
        let (fitted, r2p, r2d) =
            LatencyPredictor::fit(&prefill, &decode).unwrap();
        assert!(r2p > 0.999999 && r2d > 0.999999);
        assert!((fitted.prefill.alpha - 0.1).abs() < 1e-6);
        assert!((fitted.decode.delta - 15.85).abs() < 1e-3);
    }

    #[test]
    fn fit_with_noise_close() {
        let truth = p();
        let mut rng = Rng::new(5);
        let mut samples = Vec::new();
        for _ in 0..500 {
            let b = rng.range(1, 32) as usize;
            let l = rng.range(100, 8000) as usize;
            let ms = truth.prefill.eval(b as f64, l as f64)
                * rng.uniform(0.97, 1.03);
            samples.push(PhaseSample { batch: b, len: l, ms });
        }
        let (coeffs, r2) = fit_phase(&samples).unwrap();
        assert!(r2 > 0.99, "r2 {r2}");
        assert!((coeffs.alpha - 0.1).abs() / 0.1 < 0.05);
    }

    #[test]
    fn fit_degenerate_returns_none() {
        // all at one (b,l) point — singular design
        let s = vec![PhaseSample { batch: 1, len: 100, ms: 1.0 }; 10];
        assert!(fit_phase(&s).is_none());
        assert!(fit_phase(&s[..2]).is_none());
    }

    #[test]
    fn quantile_head_unfitted_is_identity() {
        let pred = p();
        assert_eq!(pred.lo_sigma, 0.0);
        for &q in &[0.01, 0.5, 0.9, 0.99] {
            assert_eq!(pred.quantile(q).to_bits(), 1.0f64.to_bits());
            assert_eq!(pred.lo_quantile(137, q), 137);
        }
    }

    #[test]
    fn quantile_head_is_monotone_and_median_exact() {
        let pred = p().with_lo_sigma(0.5);
        assert!(pred.quantile(0.9) > 1.0);
        assert!(pred.quantile(0.1) < 1.0);
        assert!(pred.quantile(0.99) > pred.quantile(0.9));
        // the median always returns the point prediction, same bits
        assert_eq!(pred.quantile(0.5).to_bits(), 1.0f64.to_bits());
        assert_eq!(pred.lo_quantile(200, 0.5), 200);
        // a conservative quantile rounds up, never below the prediction
        assert!(pred.lo_quantile(200, 0.9) > 200);
        // known value: exp(0.5 · Φ⁻¹(0.9)) ≈ exp(0.6408) ≈ 1.898
        assert!((pred.quantile(0.9) - 1.8979).abs() < 1e-3);
        // negative σ is clamped to the exact head
        assert_eq!(p().with_lo_sigma(-1.0).lo_sigma, 0.0);
    }

    #[test]
    fn lo_sigma_fit_recovers_known_residual_spread() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0x51_6A);
        let truth = 0.3f64;
        let pairs: Vec<(usize, usize)> = (0..4000)
            .map(|_| {
                let p = 50 + rng.below(400);
                let a = ((p as f64) * (truth * rng.normal()).exp())
                    .round()
                    .max(1.0) as usize;
                (p, a)
            })
            .collect();
        let sigma = fit_lo_sigma(&pairs);
        assert!((sigma - truth).abs() < 0.03, "fitted σ {sigma}");
        // degenerate inputs fall back to the exact head
        assert_eq!(fit_lo_sigma(&[]), 0.0);
        assert_eq!(fit_lo_sigma(&[(10, 12)]), 0.0);
        assert_eq!(fit_lo_sigma(&[(0, 5), (7, 0)]), 0.0);
    }

    #[test]
    fn chunked_prefill_sums_per_chunk_eq14() {
        let pred = p();
        // 1000 tokens in 256-chunks: 3 full + 232 remainder
        let want = pred.prefill_ms(1, 256) * 3.0 + pred.prefill_ms(1, 232);
        let got = pred.chunked_prefill_ms(1000, 256);
        assert!((got - want).abs() < 1e-9, "got {got} want {want}");
        // exact division: no remainder chunk
        let got = pred.chunked_prefill_ms(512, 256);
        assert!((got - pred.prefill_ms(1, 256) * 2.0).abs() < 1e-9);
        // chunking off or chunk >= input falls back to whole-prompt
        assert_eq!(
            pred.chunked_prefill_ms(300, 0).to_bits(),
            pred.prefill_ms(1, 300).to_bits()
        );
        assert_eq!(
            pred.chunked_prefill_ms(100, 256).to_bits(),
            pred.prefill_ms(1, 100).to_bits()
        );
        // length-proportional coefficients telescope: Σ γ·chunk = γ·input
        let lin = LatencyPredictor::new(
            PhaseCoeffs { alpha: 0.0, beta: 0.0, gamma: 2.0, delta: 0.0 },
            PhaseCoeffs::ZERO,
        );
        assert!((lin.chunked_prefill_ms(1000, 64) - 2000.0).abs() < 1e-9);
    }

    #[test]
    fn perturbation_selectors() {
        let c = p().prefill;
        assert!((c.perturbed(Coeff::Alpha, 0.5).alpha - 0.15).abs() < 1e-12);
        assert_eq!(c.perturbed(Coeff::Beta, 0.0), c);
        assert!((c.perturbed(Coeff::Delta, -0.1).delta - 43.67 * 0.9).abs()
            < 1e-9);
        assert!((c.scaled(2.0).gamma - 0.02).abs() < 1e-12);
    }
}
