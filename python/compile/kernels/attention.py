"""L1 Pallas attention kernels — the serving hot spot.

Two kernels cover the two phases of LLM inference (paper §2.1):

* :func:`flash_attention` — fused causal attention for the **prefill** phase.
  Flash-style single pass over K/V blocks with running softmax statistics, so
  the working set per grid step is one Q block + one K/V block + the f32
  accumulator, independent of sequence length.

* :func:`decode_attention` — one **decode** step: a single query token per
  (batch, head) attends over the KV cache up to a per-row position.  This is
  the TPU analogue of PagedAttention's one-pass KV scan: the cache is
  streamed block-by-block from HBM into VMEM while the running softmax state
  stays resident.

Hardware adaptation (DESIGN.md §9): the paper's stack targets CUDA GPUs; we
re-express its threadblock tiling as Pallas ``BlockSpec``s (HBM→VMEM
schedule) and size blocks for the MXU (lane = 128, f32 sublane = 8).  All
matmuls accumulate in f32 via ``preferred_element_type``.

Kernels MUST be lowered with ``interpret=True`` in this environment: real
TPU lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

# Default block sizes.  bq/bk = 128 matches the MXU tile edge; for the short
# sequences of the CPU test model we shrink to the sequence length.
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128

# Large-negative constant used instead of -inf so fully-masked blocks produce
# exp(x - m) == 0 without generating NaNs.
_MASK_VALUE = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, scale: float,
                  causal: bool):
    """One grid step: one (batch, head, q-block) against all K/V blocks.

    Refs arrive blocked as:
      q_ref: [1, 1, bq, d]   — the query block
      k_ref: [1, 1, S,  d]   — full K for this (b, h); streamed in bk chunks
      v_ref: [1, 1, S,  d]
      o_ref: [1, 1, bq, d]
    """
    q = q_ref[0, 0].astype(jnp.float32) * scale          # [bq, d]
    k = k_ref[0, 0]                                      # [S, d]
    v = v_ref[0, 0]
    bq, d = q.shape
    s = k.shape[0]
    n_kv_blocks = s // block_k

    q_block_idx = pl.program_id(2)
    q_offset = q_block_idx * bq
    q_ids = q_offset + lax.iota(jnp.int32, bq)           # global q positions

    # The KV loop is UNROLLED at trace time (static trip count, masking
    # instead of data-dependent bounds). Structurally this is what a TPU
    # pipeline wants (static schedule -> double-bufferable HBM->VMEM DMAs)
    # and it is dramatically faster under interpret mode on CPU PJRT,
    # where dynamic-trip-count while-loops defeat the XLA optimizer
    # (EXPERIMENTS.md §Perf: 44x on the decode path).
    m = jnp.full((bq,), _MASK_VALUE, dtype=jnp.float32)
    l = jnp.zeros((bq,), dtype=jnp.float32)
    acc = jnp.zeros((bq, d), dtype=jnp.float32)
    for j in range(n_kv_blocks):
        k_blk = k[j * block_k:(j + 1) * block_k]
        v_blk = v[j * block_k:(j + 1) * block_k]
        # scores: [bq, bk], accumulated in f32 on the MXU.
        scores = jnp.dot(q, k_blk.T.astype(jnp.float32),
                         preferred_element_type=jnp.float32)
        if causal:
            k_ids = j * block_k + lax.iota(jnp.int32, block_k)
            mask = k_ids[None, :] <= q_ids[:, None]
            scores = jnp.where(mask, scores, _MASK_VALUE)
        m_cur = jnp.max(scores, axis=1)                  # [bq]
        m_new = jnp.maximum(m, m_cur)
        correction = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new[:, None])             # [bq, bk]
        l = l * correction + jnp.sum(p, axis=1)
        acc = acc * correction[:, None] + jnp.dot(
            p, v_blk.astype(jnp.float32),
            preferred_element_type=jnp.float32)
        m = m_new
    out = acc / l[:, None]
    o_ref[0, 0] = out.astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    block_q: int | None = None,
                    block_k: int | None = None,
                    interpret: bool = True):
    """Fused multi-head attention over ``[B, H, S, D]`` tensors.

    Args:
      q, k, v: ``[batch, heads, seq, head_dim]`` arrays (f32 or bf16).
      causal: apply a causal mask (token *i* attends to keys ``<= i``).
      block_q / block_k: VMEM tile sizes along the sequence axis; both must
        divide ``seq``.  Defaults adapt to short sequences.
      interpret: run the Pallas interpreter (required on CPU PJRT).

    Returns:
      ``[batch, heads, seq, head_dim]`` attention output in ``q.dtype``.
    """
    b, h, s, d = q.shape
    if k.shape != (b, h, s, d) or v.shape != (b, h, s, d):
        raise ValueError(f"q/k/v shape mismatch: {q.shape} {k.shape} {v.shape}")
    bq = block_q or min(DEFAULT_BLOCK_Q, s)
    bk = block_k or min(DEFAULT_BLOCK_K, s)
    if s % bq or s % bk:
        raise ValueError(f"seq {s} not divisible by blocks ({bq}, {bk})")
    scale = 1.0 / math.sqrt(d)

    grid = (b, h, s // bq)
    kernel = functools.partial(_flash_kernel, block_k=bk, scale=scale,
                               causal=causal)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, s, d), lambda b_, h_, i: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, s, d), lambda b_, h_, i: (b_, h_, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        interpret=interpret,
    )(q, k, v)


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, *, block_k: int,
                   scale: float):
    """One grid step: one (batch, head) decode query against the KV cache.

    Refs:
      pos_ref: [1]              — this row's current position (0-based index
                                  of the slot the new token occupies; keys
                                  ``<= pos`` are valid).
      q_ref:   [1, 1, d]
      k_ref:   [1, 1, S, d]     — cache for this (b, h); streamed in chunks
      v_ref:   [1, 1, S, d]
      o_ref:   [1, 1, d]
    """
    pos = pos_ref[0]
    q = q_ref[0, 0].astype(jnp.float32) * scale          # [d]
    k = k_ref[0, 0]                                      # [S, d]
    v = v_ref[0, 0]
    s, d = k.shape
    n_blocks_total = s // block_k

    # Static trip count, mask by pos (see the note in _flash_kernel: trace-
    # time unrolling keeps the schedule static for both the TPU pipeline
    # and the CPU interpret path; blocks past pos contribute zero weight).
    m = jnp.float32(_MASK_VALUE)
    l = jnp.float32(0.0)
    acc = jnp.zeros((d,), dtype=jnp.float32)
    for j in range(n_blocks_total):
        k_blk = k[j * block_k:(j + 1) * block_k]
        v_blk = v[j * block_k:(j + 1) * block_k]
        scores = jnp.dot(k_blk.astype(jnp.float32), q,
                         preferred_element_type=jnp.float32)  # [bk]
        k_ids = j * block_k + lax.iota(jnp.int32, block_k)
        scores = jnp.where(k_ids <= pos, scores, _MASK_VALUE)
        m_cur = jnp.max(scores)
        m_new = jnp.maximum(m, m_cur)
        correction = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new)                      # [bk]
        l = l * correction + jnp.sum(p)
        acc = acc * correction + jnp.dot(
            p, v_blk.astype(jnp.float32),
            preferred_element_type=jnp.float32)          # [d]
        m = m_new
    o_ref[0, 0] = (acc / l).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, pos, *, block_k: int | None = None,
                     interpret: bool = True):
    """Single-token decode attention against a (padded) KV cache.

    Args:
      q:        ``[batch, heads, head_dim]`` — the new token's query.
      k_cache:  ``[batch, heads, max_seq, head_dim]`` — keys; slots beyond
                ``pos`` may hold garbage (they are masked).
      v_cache:  same shape as ``k_cache``.
      pos:      ``[batch]`` int32 — index of the new token's slot per row;
                the row attends over keys ``0..=pos`` (the new token's K/V
                must already be written at ``pos``).
      block_k:  KV streaming chunk; must divide ``max_seq``.

    Returns:
      ``[batch, heads, head_dim]`` in ``q.dtype``.
    """
    b, h, d = q.shape
    bc, hc, s, dc = k_cache.shape
    if (bc, hc, dc) != (b, h, d) or v_cache.shape != k_cache.shape:
        raise ValueError(
            f"cache shape mismatch: q={q.shape} k={k_cache.shape} v={v_cache.shape}")
    if pos.shape != (b,):
        raise ValueError(f"pos shape {pos.shape} != ({b},)")
    bk = block_k or min(DEFAULT_BLOCK_K, s)
    if s % bk:
        raise ValueError(f"max_seq {s} not divisible by block_k {bk}")
    scale = 1.0 / math.sqrt(d)

    grid = (b, h)
    kernel = functools.partial(_decode_kernel, block_k=bk, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b_, h_: (b_,)),
            pl.BlockSpec((1, 1, d), lambda b_, h_: (b_, h_, 0)),
            pl.BlockSpec((1, 1, s, d), lambda b_, h_: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, s, d), lambda b_, h_: (b_, h_, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda b_, h_: (b_, h_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        interpret=interpret,
    )(pos.astype(jnp.int32), q, k_cache, v_cache)
