//! Multi-instance SLO-aware scheduling (paper §4.4, Algorithm 2).
//!
//! The scheduling solution decomposes into **instance assignment** followed
//! by **per-instance priority mapping** (run independently — the paper
//! notes the mappings are parallelizable across instances, which this
//! implementation exploits with scoped threads):
//!
//! 1. predict request latencies;
//! 2. assign requests round-robin to the instance with the largest
//!    remaining memory — accounted in KV blocks via Eq. 20
//!    ([`InstanceInfo::pool_blocks`]); when the largest remaining capacity
//!    cannot host the next request, remaining capacities are reset — a new
//!    "iteration" of assignments begins. A request no instance can ever
//!    host is a hard scheduling error;
//! 3. run Algorithm 1 inside each instance — one scoped thread per
//!    instance, since the searches share nothing but the immutable
//!    predictor and their own job slices. With KV enforcement on
//!    ([`crate::coordinator::kv::KvMode`]), each instance's search is
//!    additionally bound to its own block pool, so planned batches never
//!    overcommit at execution time;
//! 4. enqueue each instance's priority sequence for execution.
//!
//! [`ScheduleOutcome`] reports the scheduling overhead both ways: wall
//! clock (what the parallel mapping actually costs) and CPU time (the sum
//! of per-instance mapping times — the quantity comparable to the paper's
//! Fig. 11(B), whose instances are mapped sequentially on one server).

use anyhow::{bail, Result};

use crate::coordinator::kv::{self, KvConfig, KvMode};
use crate::coordinator::objective::{Evaluator, Job, Schedule};
use crate::coordinator::predictor::LatencyPredictor;
use crate::coordinator::priority::annealing::{
    priority_mapping, SaParams, SaResult, SearchStats,
};
use crate::coordinator::profiler::MemoryModel;
use crate::coordinator::request::Request;

/// Static description of one LLM inference instance.
#[derive(Debug, Clone, Copy)]
pub struct InstanceInfo {
    pub id: usize,
    /// KV-cache memory pool size (MB).
    pub mem_mb: f64,
}

impl InstanceInfo {
    /// This instance's KV pool in blocks, through Eq. 20
    /// (`token_num(m) = m·μ/σ`) at `block_tokens` granularity.
    pub fn pool_blocks(&self, mem: &MemoryModel, block_tokens: usize) -> u64 {
        kv::pool_blocks_from_mb(self.mem_mb, mem, block_tokens)
    }
}

/// Per-instance execution plan produced by the scheduler.
#[derive(Debug, Clone)]
pub struct InstancePlan {
    pub instance: usize,
    /// Scheduler's job views (with predicted output lengths); `req_idx`
    /// points into the request slice given to [`schedule`].
    pub jobs: Vec<Job>,
    /// Priority sequence + batch partition over `jobs` (local indices).
    pub schedule: Schedule,
    pub stats: SearchStats,
}

impl InstancePlan {
    /// Request indices in execution order.
    pub fn request_order(&self) -> Vec<usize> {
        self.schedule.order.iter().map(|&j| self.jobs[j].req_idx).collect()
    }
}

/// Result of Algorithm 2 over one wave of requests.
#[derive(Debug, Clone)]
pub struct ScheduleOutcome {
    pub plans: Vec<InstancePlan>,
    /// Wall-clock scheduling overhead (ms): assignment plus the *parallel*
    /// per-instance mapping section. This is what a caller actually waits.
    pub overhead_ms: f64,
    /// CPU-time scheduling overhead (ms): assignment plus the *sum* of
    /// per-instance [`SearchStats::cpu_ms`] — which itself sums busy time
    /// across that instance's tempered chains, so with `chains > 1` this
    /// is Σ over chains × instances. Comparable to the paper's Fig. 11(B)
    /// numbers, whose instances are mapped sequentially on one server —
    /// report this, not `overhead_ms`, when reproducing that figure.
    pub cpu_ms: f64,
    /// Accepted best-exchanges summed across every instance's tempered
    /// search ([`SearchStats::exchanges`]); 0 at `chains == 1`.
    pub exchanges: usize,
    /// Base RNG seed the wave was planned with (each instance searches at
    /// [`instance_seed`] of it). Recorded so a plan — and the bench JSON
    /// rows derived from it — can be reproduced exactly.
    pub seed: u64,
}

/// Per-instance search seed derived from the wave's base seed: instances
/// explore independently, and the derivation is shared with the online
/// path ([`crate::coordinator::online`]) so a single-instance online run
/// with t=0 arrivals replays the closed-wave search bit for bit.
pub fn instance_seed(base: u64, inst: usize) -> u64 {
    base.wrapping_add(inst as u64).wrapping_mul(0x9E3779B9)
}

/// Instance assignment (Algorithm 2 line 4, "Instance Assignment" ¶).
///
/// Requests are considered in arrival order; each goes to the instance
/// with the largest remaining memory. All accounting is in **KV blocks**
/// (the same Eq. 20 conversion plus block rounding the SA search and the
/// engine allocator use): a request's footprint is its total token count
/// (input + predicted output) rounded up to blocks, and an instance's
/// capacity is [`InstanceInfo::pool_blocks`]. If even the largest-
/// remaining instance lacks room, all remaining capacities reset (a
/// maximum-capacity wave has been packed) and assignment continues.
///
/// # Errors
/// A request whose footprint alone exceeds **every** instance's pool can
/// never execute; assignment fails with a descriptive error instead of
/// silently overcommitting (the pre-KV behaviour let the remaining-memory
/// counter go negative).
pub fn assign_instances(
    requests: &[Request],
    predicted_out: &[usize],
    instances: &[InstanceInfo],
    mem: &MemoryModel,
    block_tokens: usize,
) -> Result<Vec<Vec<usize>>> {
    assert_eq!(requests.len(), predicted_out.len());
    assert!(!instances.is_empty());
    let block_tokens = block_tokens.max(1);
    let pools: Vec<u64> = instances
        .iter()
        .map(|i| i.pool_blocks(mem, block_tokens))
        .collect();
    let mut remaining: Vec<u64> = pools.clone();
    let mut out: Vec<Vec<usize>> = vec![Vec::new(); instances.len()];

    // Integer blocks: NaN/negative capacities became empty pools in the
    // Eq. 20 conversion, so a plain max suffices (ties keep the previous
    // float-path behaviour of picking the last maximal instance).
    fn largest(remaining: &[u64]) -> usize {
        remaining
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.cmp(b.1))
            .map(|(i, _)| i)
            .unwrap()
    }

    for (ri, req) in requests.iter().enumerate() {
        let tokens = req.input_len + predicted_out[ri];
        let need = kv::blocks_for(tokens, block_tokens);
        // pick instance with the largest remaining capacity
        let mut best = largest(&remaining);
        if remaining[best] < need {
            // reset: a full wave has been packed (§4.4); re-scan since the
            // globally-largest instance may differ from the current one
            remaining.copy_from_slice(&pools);
            best = largest(&remaining);
            if remaining[best] < need {
                bail!(
                    "request {ri} (id {}): KV footprint of {need} blocks \
                     ({tokens} tokens at {block_tokens} tokens/block) \
                     exceeds every instance's pool (largest: {} blocks) — \
                     the request can never be scheduled",
                    req.id,
                    remaining[best],
                );
            }
        }
        remaining[best] -= need;
        out[best].push(ri);
    }
    Ok(out)
}

/// Algorithm 2: full SLO-aware scheduling across instances.
///
/// `predicted_out[i]` is the predicted output length for `requests[i]`
/// (from the profiler or an oracle — the Fig. 9 knob). Per-instance
/// priority mappings run on scoped threads (one per non-trivial instance);
/// plan order is deterministic (by instance index) and each instance's
/// search keeps its own derived RNG seed, so results are identical to the
/// sequential execution.
///
/// **KV threading**: instance assignment always accounts in Eq. 20 blocks.
/// When `sa.kv` enforces a pool ([`KvMode::Hard`]/[`KvMode::Soft`]), each
/// instance's search additionally runs against *its own* pool — the
/// smaller of the instance's [`InstanceInfo::pool_blocks`] and any
/// engine-level cap in `sa.kv.pool_blocks` — replacing the old standalone
/// Eq. 20 check with end-to-end feasibility. `sa.kv.phase` flows into the
/// per-instance searches unchanged, so a
/// [`crate::coordinator::kv::KvPhaseModel::Phased`] config prices each
/// planned batch at its occupancy peak; *assignment* itself keeps the
/// conservative full-footprint accounting (requests from one wave may
/// coexist across batches, and reserve sums bound every phased peak).
/// With the default unlimited config the searches are bit-identical to
/// the pre-KV scheduler.
///
/// # Errors
/// Fails when a request's KV footprint exceeds every instance's pool
/// (see [`assign_instances`]).
pub fn schedule(
    requests: &[Request],
    predicted_out: &[usize],
    instances: &[InstanceInfo],
    predictor: &LatencyPredictor,
    mem: &MemoryModel,
    sa: &SaParams,
) -> Result<ScheduleOutcome> {
    let t0 = crate::util::now_ms();
    let assignment = assign_instances(
        requests,
        predicted_out,
        instances,
        mem,
        sa.kv.block_tokens,
    )?;
    let assign_ms = crate::util::now_ms() - t0;

    // Materialize per-instance job sets first so the mapping threads borrow
    // only immutable data.
    let job_sets: Vec<Vec<Job>> = assignment
        .iter()
        .map(|req_indices| {
            req_indices
                .iter()
                .map(|&ri| {
                    Job::from_request(ri, &requests[ri], predicted_out[ri])
                })
                .collect()
        })
        .collect();
    // Derive a per-instance seed so instances explore independently, and
    // bind each search to its instance's KV pool when enforcement is on.
    let params: Vec<SaParams> = (0..job_sets.len())
        .map(|inst| SaParams {
            seed: instance_seed(sa.seed, inst),
            kv: match sa.kv.mode {
                KvMode::Unlimited => sa.kv,
                _ => KvConfig {
                    pool_blocks: sa.kv.pool_blocks.min(
                        instances[inst].pool_blocks(mem, sa.kv.block_tokens),
                    ),
                    ..sa.kv
                },
            },
            ..*sa
        })
        .collect();

    let busy = job_sets.iter().filter(|jobs| !jobs.is_empty()).count();
    let results: Vec<SaResult> = if busy <= 1 {
        // Thread spawn costs more than a trivial mapping; stay inline.
        job_sets
            .iter()
            .zip(&params)
            .map(|(jobs, p)| priority_mapping(&Evaluator::new(jobs, predictor), p))
            .collect()
    } else {
        std::thread::scope(|scope| {
            // Threads only for instances with work; empty mappings return
            // immediately and are cheaper than a spawn.
            let handles: Vec<_> = job_sets
                .iter()
                .zip(&params)
                .map(|(jobs, p)| {
                    if jobs.is_empty() {
                        None
                    } else {
                        Some(scope.spawn(move || {
                            priority_mapping(&Evaluator::new(jobs, predictor), p)
                        }))
                    }
                })
                .collect();
            handles
                .into_iter()
                .zip(job_sets.iter().zip(&params))
                .map(|(h, (jobs, p))| match h {
                    Some(h) => {
                        h.join().expect("priority-mapping thread panicked")
                    }
                    None => {
                        priority_mapping(&Evaluator::new(jobs, predictor), p)
                    }
                })
                .collect()
        })
    };

    // cpu_ms (not overhead_ms): each instance's figure already folds in
    // the busy time of its concurrent tempered chains.
    let mapping_cpu_ms: f64 = results.iter().map(|r| r.stats.cpu_ms).sum();
    let exchanges: usize = results.iter().map(|r| r.stats.exchanges).sum();
    let plans: Vec<InstancePlan> = job_sets
        .into_iter()
        .zip(results)
        .enumerate()
        .map(|(inst, (jobs, result))| InstancePlan {
            instance: inst,
            jobs,
            schedule: result.schedule,
            stats: result.stats,
        })
        .collect();

    Ok(ScheduleOutcome {
        plans,
        overhead_ms: crate::util::now_ms() - t0,
        cpu_ms: assign_ms + mapping_cpu_ms,
        exchanges,
        seed: sa.seed,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{Slo, TaskType};
    use crate::util::prop::check;

    fn req(id: u64, input: usize, output: usize) -> Request {
        Request::synthetic(
            id,
            TaskType::Code,
            input,
            output,
            Slo::E2e { e2e_ms: 30_000.0 },
        )
    }

    fn instances(n: usize, mem_mb: f64) -> Vec<InstanceInfo> {
        (0..n).map(|id| InstanceInfo { id, mem_mb }).collect()
    }

    #[test]
    fn assignment_balances_memory() {
        let mem = MemoryModel { utility: 1.0, mb_per_token: 1.0 };
        let reqs: Vec<Request> =
            (0..6).map(|i| req(i, 100, 0)).collect();
        let outs = vec![0usize; 6];
        let asg =
            assign_instances(&reqs, &outs, &instances(2, 10_000.0), &mem, 16)
                .unwrap();
        // equal-size requests alternate between equal instances
        assert_eq!(asg[0].len(), 3);
        assert_eq!(asg[1].len(), 3);
    }

    #[test]
    fn assignment_prefers_larger_memory() {
        let mem = MemoryModel { utility: 1.0, mb_per_token: 1.0 };
        let reqs: Vec<Request> = (0..4).map(|i| req(i, 10, 0)).collect();
        let outs = vec![0usize; 4];
        let inst = vec![
            InstanceInfo { id: 0, mem_mb: 100.0 },
            InstanceInfo { id: 1, mem_mb: 10_000.0 },
        ];
        let asg = assign_instances(&reqs, &outs, &inst, &mem, 16).unwrap();
        // the big instance keeps winning until its remaining dips below
        assert!(asg[1].len() >= 3, "{asg:?}");
    }

    #[test]
    fn assignment_resets_when_full() {
        let mem = MemoryModel { utility: 1.0, mb_per_token: 1.0 };
        // each request needs 5 blocks; the instance holds 6 (100 tokens at
        // 16 tokens/block) -> the pool resets on every second request
        let reqs: Vec<Request> = (0..5).map(|i| req(i, 80, 0)).collect();
        let outs = vec![0usize; 5];
        let asg = assign_instances(&reqs, &outs, &instances(1, 100.0), &mem, 16)
            .unwrap();
        assert_eq!(asg[0].len(), 5); // all still assigned (across waves)
    }

    #[test]
    fn assignment_rejects_request_larger_than_every_pool() {
        let mem = MemoryModel { utility: 1.0, mb_per_token: 1.0 };
        // 100-token pool (6 blocks); a 200-token request needs 13 blocks
        let reqs = vec![req(0, 150, 50)];
        let outs = vec![50usize];
        let err =
            assign_instances(&reqs, &outs, &instances(2, 100.0), &mem, 16)
                .unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("KV footprint"), "unhelpful error: {msg}");
        assert!(msg.contains("13 blocks"), "unhelpful error: {msg}");
    }

    #[test]
    fn assignment_covers_all_requests() {
        check("assignment partitions requests", 100, |rng| {
            let n_req = 1 + rng.below(40);
            let n_inst = 1 + rng.below(4);
            let reqs: Vec<Request> = (0..n_req)
                .map(|i| {
                    req(i as u64, 1 + rng.below(2000), rng.below(500))
                })
                .collect();
            let outs: Vec<usize> =
                reqs.iter().map(|r| r.output_len).collect();
            let mem = MemoryModel::default();
            let asg = assign_instances(
                &reqs,
                &outs,
                &instances(n_inst, 16_000.0),
                &mem,
                16,
            )
            .map_err(|e| e.to_string())?;
            let mut seen = vec![false; n_req];
            for list in &asg {
                for &ri in list {
                    if seen[ri] {
                        return Err(format!("request {ri} assigned twice"));
                    }
                    seen[ri] = true;
                }
            }
            if !seen.iter().all(|&s| s) {
                return Err("request dropped".into());
            }
            Ok(())
        });
    }

    #[test]
    fn assignment_survives_nan_capacity() {
        // a NaN pool converts to zero blocks (Eq. 20 derivation): the
        // broken instance must neither panic nor absorb the wave.
        let mem = MemoryModel { utility: 1.0, mb_per_token: 1.0 };
        let reqs: Vec<Request> = (0..4).map(|i| req(i, 10, 0)).collect();
        let outs = vec![0usize; 4];
        let inst = vec![
            InstanceInfo { id: 0, mem_mb: f64::NAN },
            InstanceInfo { id: 1, mem_mb: 1_000.0 },
        ];
        assert_eq!(inst[0].pool_blocks(&mem, 16), 0);
        let asg = assign_instances(&reqs, &outs, &inst, &mem, 16).unwrap();
        assert_eq!(asg.iter().map(Vec::len).sum::<usize>(), 4);
        assert_eq!(asg[1].len(), 4, "{asg:?}");
    }

    #[test]
    fn schedule_produces_valid_plans() {
        let reqs: Vec<Request> = (0..12)
            .map(|i| req(i, 100 + 50 * i as usize, 20 + 10 * i as usize))
            .collect();
        let outs: Vec<usize> = reqs.iter().map(|r| r.output_len).collect();
        let predictor = LatencyPredictor::paper_table2();
        let mem = MemoryModel::default();
        let sa = SaParams::with_max_batch(4);
        let outcome = schedule(
            &reqs,
            &outs,
            &instances(3, 16_000.0),
            &predictor,
            &mem,
            &sa,
        )
        .unwrap();
        assert_eq!(outcome.plans.len(), 3);
        let mut all: Vec<usize> = Vec::new();
        for plan in &outcome.plans {
            plan.schedule.validate(4).unwrap();
            assert_eq!(plan.schedule.len(), plan.jobs.len());
            all.extend(plan.request_order());
        }
        all.sort_unstable();
        assert_eq!(all, (0..12).collect::<Vec<_>>());
        assert!(outcome.overhead_ms >= 0.0);
        assert!(outcome.cpu_ms >= 0.0);
        assert_eq!(outcome.seed, sa.seed); // reproducibility record
        // cpu time covers every instance's mapping; each one individually
        // can never exceed the total
        for plan in &outcome.plans {
            assert!(plan.stats.overhead_ms <= outcome.cpu_ms + 1e-9);
        }
    }

    #[test]
    fn parallel_mapping_is_deterministic() {
        let reqs: Vec<Request> = (0..16)
            .map(|i| req(i, 100 + 37 * i as usize, 10 + 9 * i as usize))
            .collect();
        let outs: Vec<usize> = reqs.iter().map(|r| r.output_len).collect();
        let predictor = LatencyPredictor::paper_table2();
        let mem = MemoryModel::default();
        let sa = SaParams::with_max_batch(4);
        let a = schedule(&reqs, &outs, &instances(4, 16_000.0), &predictor, &mem, &sa)
            .unwrap();
        let b = schedule(&reqs, &outs, &instances(4, 16_000.0), &predictor, &mem, &sa)
            .unwrap();
        assert_eq!(a.plans.len(), b.plans.len());
        for (pa, pb) in a.plans.iter().zip(&b.plans) {
            assert_eq!(pa.instance, pb.instance);
            assert_eq!(pa.schedule, pb.schedule);
        }
    }

    #[test]
    fn single_instance_gets_everything() {
        let reqs: Vec<Request> = (0..5).map(|i| req(i, 100, 10)).collect();
        let outs = vec![10usize; 5];
        let outcome = schedule(
            &reqs,
            &outs,
            &instances(1, 16_000.0),
            &LatencyPredictor::paper_table2(),
            &MemoryModel::default(),
            &SaParams::with_max_batch(2),
        )
        .unwrap();
        assert_eq!(outcome.plans[0].jobs.len(), 5);
    }

    #[test]
    fn hard_kv_schedule_binds_each_instance_to_its_pool() {
        use crate::coordinator::kv::{KvConfig, KvMode};
        let mem = MemoryModel { utility: 1.0, mb_per_token: 1.0 };
        // 1024-token pools -> 64 blocks each; requests of ~200 tokens
        // (13 blocks) so a max_batch of 8 would overcommit (104 blocks)
        // without KV-aware search.
        let reqs: Vec<Request> =
            (0..12).map(|i| req(i, 150, 50)).collect();
        let outs = vec![50usize; 12];
        let kv = KvConfig::from_pool_mb(1024.0, &mem, 16, KvMode::Hard);
        assert_eq!(kv.pool_blocks, 64);
        let sa = SaParams { kv, ..SaParams::with_max_batch(8) };
        let outcome = schedule(
            &reqs,
            &outs,
            &instances(2, 1024.0),
            &LatencyPredictor::paper_table2(),
            &mem,
            &sa,
        )
        .unwrap();
        for plan in &outcome.plans {
            let ev = Evaluator::new(
                &plan.jobs,
                &LatencyPredictor::paper_table2(),
            );
            assert_eq!(
                ev.kv_excess(&plan.schedule, &kv),
                0,
                "instance {} overcommits: {:?}",
                plan.instance,
                plan.schedule
            );
        }
    }
}
