"""AOT artifact contract tests: weights container, manifest, HLO text.

The Rust runtime (rust/src/runtime/mod.rs) trusts this format; these tests
pin it down on the producer side.
"""

import json
import os
import struct

import jax
import numpy as np
import pytest

from compile import aot, model as M

jax.config.update("jax_platform_name", "cpu")

TINY = M.ModelConfig(d_model=32, n_layers=1, n_heads=2, head_dim=16,
                     d_ffn=64, max_seq=64)


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.build(out, TINY, seed=3, prefill_batches=(1, 2),
                         prefill_seqs=(16, 32), decode_batches=(1,),
                         verbose=False)
    return out, manifest


def read_weights(path):
    """Reference decoder for the TLMW1 container."""
    tensors = {}
    with open(path, "rb") as f:
        assert f.read(6) == b"TLMW1\0"
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode("utf-8")
            dtype, ndim = struct.unpack("<BB", f.read(2))
            assert dtype == 0
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            n = int(np.prod(dims)) if dims else 1
            data = np.frombuffer(f.read(4 * n), np.float32).reshape(dims)
            tensors[name] = data
        assert f.read() == b""  # no trailing bytes
    return tensors


def test_weights_roundtrip(built):
    out, _ = built
    tensors = read_weights(os.path.join(out, "weights.bin"))
    params = M.init_params(TINY, seed=3)
    assert list(tensors.keys()) == M.param_order(TINY)
    for name, arr in tensors.items():
        np.testing.assert_array_equal(arr, np.asarray(params[name]))


def test_manifest_contents(built):
    out, manifest = built
    with open(os.path.join(out, "manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk == manifest
    assert on_disk["model"]["d_model"] == TINY.d_model
    assert on_disk["tokens"] == {"vocab": M.VOCAB_SIZE, "bos": M.BOS_ID,
                                 "eos": M.EOS_ID}
    names = [p["name"] for p in on_disk["params"]]
    assert names == M.param_order(TINY)
    shapes = M.param_shapes(TINY)
    for p in on_disk["params"]:
        assert tuple(p["shape"]) == shapes[p["name"]]


def test_manifest_buckets_exist(built):
    out, manifest = built
    assert len(manifest["buckets"]["prefill"]) == 4   # 2 batches × 2 seqs
    assert len(manifest["buckets"]["decode"]) == 1
    for entry in (manifest["buckets"]["prefill"]
                  + manifest["buckets"]["decode"]):
        path = os.path.join(out, entry["file"])
        assert os.path.getsize(path) > 1000


def test_hlo_is_text_with_entry(built):
    out, manifest = built
    path = os.path.join(out, manifest["buckets"]["prefill"][0]["file"])
    with open(path) as f:
        text = f.read()
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # interchange must be text, never a serialized proto blob
    assert "\x00" not in text


def test_hlo_param_arity(built):
    """Entry computation must take n_params + data args (decode: k, v,
    tokens, pos)."""
    out, manifest = built
    n_params = len(manifest["params"])
    path = os.path.join(out, manifest["buckets"]["decode"][0]["file"])
    with open(path) as f:
        header = f.readline()
    assert "entry_computation_layout" in header
    args_part = header[header.index("{(") + 2:header.index(")->")]
    n_args = args_part.count("f32[") + args_part.count("s32[")
    assert n_args == n_params + 4


def test_bucket_seq_filtered_by_max_seq(tmp_path):
    cfg = M.ModelConfig(d_model=32, n_layers=1, n_heads=2, head_dim=16,
                        d_ffn=64, max_seq=32)
    manifest = aot.build(str(tmp_path), cfg, prefill_batches=(1,),
                         prefill_seqs=(16, 32, 64), decode_batches=(1,),
                         verbose=False)
    seqs = [b["seq"] for b in manifest["buckets"]["prefill"]]
    assert seqs == [16, 32]
